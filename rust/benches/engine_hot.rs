//! L3 hot-path microbenchmarks (§Perf): encode / gather+hash / lookup /
//! full ensemble inference on the native engine, the bit-sliced batch
//! kernel, the fused slice path and the sharded engine, plus the PJRT
//! engine for comparison when built with `--features pjrt` and artifacts
//! exist. This is the bench the optimization loop in EXPERIMENTS.md
//! §Perf iterates against.
//!
//! Headline numbers: the batch-kernel sweep (per-sample vs bit-sliced
//! throughput at batch ≥ 256, target ≥ 4× single-thread), the fused
//! sweep (fused slice path vs the PR-1 encode+transpose+kernel sequence
//! at batch 256, target ≥ 1.5×) and the simd sweep (dispatched kernel
//! tier vs forced scalar at batch 256 — bit-exactness asserted; the
//! ≥ 1.3× speedup gate arms only with ULEEN_GATE_SIMD set on an AVX2
//! host), then the shard sweep, the zoo cascade
//! sweep (tier-pinned Fast/Accurate vs the batched confidence cascade
//! at batch 256), and the cascade×shard sweep (`ShardedRouterEngine` at
//! batch 256, with an asserted merge gate: pool-merged per-tier counters
//! bit-exact with the single-router cascade, zero per-worker model
//! clones Arc-witnessed) on top. The autopilot sweep closes the set:
//! bursty under-filled traffic against a zoo server whose static config
//! (margin 0.9, dwell 5 ms) structurally misses a 2 ms p99 target, run
//! twice — knobs frozen vs steered by `coordinator::autopilot` — with
//! the "autopilot holds the target the static config misses AND both
//! knobs moved" gate armed by ULEEN_GATE_AUTOPILOT (nightly).
//!
//! Flags (after `--`, e.g. `cargo bench --bench engine_hot -- --json`):
//! * `--json`  — also emit `BENCH_engine_hot.json` (stage → ns/sample,
//!   samples/s, plus the acceptance ratios) so the perf trajectory is
//!   machine-readable across PRs.
//! * `--smoke` — low iteration counts and trimmed sweeps; a release-mode
//!   CI run that still exercises every stage under optimization.

use uleen::bench::harness::{bench_fn, BenchResult};

// Built with `--features alloc-witness`, the whole bench runs under the
// counting allocator so the allocs-per-batch gate below can assert the
// fused native path is allocation-free in steady state.
#[cfg(feature = "alloc-witness")]
#[global_allocator]
static ALLOC_WITNESS: uleen::util::alloc_witness::CountingAlloc =
    uleen::util::alloc_witness::CountingAlloc;
use uleen::coordinator::autopilot::{Autopilot, AutopilotConfig};
use uleen::coordinator::batcher::BatcherConfig;
use uleen::coordinator::http::{client, HttpConfig, HttpFrontend};
use uleen::coordinator::router::{ModelRouter, Tier};
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_mnist;
use uleen::model::ensemble::EnsembleScratch;
use uleen::model::flat::{CompileOptions, FlatBatchScratch, FlatModel};
use uleen::model::simd::{KernelPath, MaskWidth};
use uleen::model::submodel::SubmodelScratch;
use uleen::runtime::{InferenceEngine, NativeEngine, SharedModel, ShardedEngine, ShardedRouterEngine};
use uleen::util::bitvec::BitVec;
use uleen::util::json::Json;
use uleen::util::stats::percentile;
#[cfg(feature = "pjrt")]
use uleen::runtime::PjrtEngine;

/// The multi-shot artifact when available, else a one-shot stand-in with
/// the same shape class — the kernel sweeps must run in offline checkouts.
fn load_or_train(ds: &uleen::data::Dataset) -> uleen::model::ensemble::UleenModel {
    match uleen::bench::load_model("uln_s.uln") {
        Ok((model, _)) => model,
        Err(e) => {
            println!("(no artifact: {e} — falling back to a one-shot model)");
            uleen::train::oneshot::train_oneshot(
                ds,
                &uleen::train::oneshot::OneShotConfig {
                    inputs_per_filter: 16,
                    entries_per_filter: 256,
                    therm_bits: 4,
                    ..Default::default()
                },
            )
            .0
        }
    }
}

/// Record + print one stage result.
fn record(report: &mut Vec<(String, BenchResult)>, r: BenchResult) {
    println!("{}", r.summary());
    report.push((r.name.clone(), r));
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_out = args.iter().any(|a| a == "--json");
    // (warmup, iters) pairs: full-fidelity vs CI smoke
    let (w_hot, i_hot) = if smoke { (1, 3) } else { (3, 30) };
    let (w_swp, i_swp) = if smoke { (1, 2) } else { (2, 12) };

    let ds = synth_mnist(2024, 64, 1024);
    let model = load_or_train(&ds);
    let n = 256usize;
    let mut report: Vec<(String, BenchResult)> = Vec::new();
    println!(
        "== engine_hot: native hot-path stages ({}, {n} samples/iter{}) ==",
        model.name,
        if smoke { ", SMOKE" } else { "" }
    );

    // stage 1: thermometer encode
    let enc = model.encoder.clone();
    let r = bench_fn("encode", w_hot, i_hot, n as f64, || {
        for i in 0..n {
            std::hint::black_box(enc.encode(ds.test_row(i)));
        }
    });
    record(&mut report, r);

    // stage 2: gather + hash (submodel 0)
    let sm = model.submodels[0].clone();
    let encoded: Vec<_> = (0..n).map(|i| enc.encode(ds.test_row(i))).collect();
    let mut scratch = SubmodelScratch::default();
    let r = bench_fn("gather+hash (SM0)", w_hot, i_hot, n as f64, || {
        for e in &encoded {
            sm.gather_keys(e, &mut scratch.keys);
            sm.hash_keys(&scratch.keys, &mut scratch.idxs);
            std::hint::black_box(&scratch.idxs);
        }
    });
    record(&mut report, r);

    // stage 3: full submodel responses (lookup included)
    let mut out = vec![0i32; model.num_classes()];
    let r = bench_fn("submodel responses (SM0)", w_hot, i_hot, n as f64, || {
        for e in &encoded {
            sm.responses(e, &mut scratch, &mut out);
            std::hint::black_box(&out);
        }
    });
    record(&mut report, r);

    // stage 4: end-to-end ensemble predict from raw pixels
    let mut es = EnsembleScratch::default();
    let r = bench_fn("ensemble predict e2e", w_hot, i_hot, n as f64, || {
        for i in 0..n {
            std::hint::black_box(model.predict(ds.test_row(i), &mut es));
        }
    });
    record(&mut report, r);
    let native_ips = report.last().unwrap().1.throughput_per_sec();

    // == batch sweep: per-sample path vs bit-sliced batch kernel ==
    println!("\n== batch sweep: per-sample vs bit-sliced kernel (single thread) ==");
    let f = model.encoder.num_inputs;
    let m = model.num_classes();
    let mut native = NativeEngine::new(model.clone());
    let mut speedup_at = Vec::new();
    let batches: &[usize] = if smoke { &[256] } else { &[64, 256, 1024] };
    for &bs in batches {
        let x = &ds.test_x[..bs * f];
        // baseline: the scalar path, forced by n=1 submissions
        let r1 = bench_fn(&format!("per-sample ×{bs}"), w_swp, i_swp, bs as f64, || {
            for i in 0..bs {
                std::hint::black_box(
                    native.responses(&x[i * f..(i + 1) * f], 1).unwrap(),
                );
            }
        });
        let t1 = r1.throughput_per_sec();
        record(&mut report, r1);
        // bit-sliced + fused encode: one call, 64-sample tiles
        let rb = bench_fn(&format!("bit-sliced  ×{bs}"), w_swp, i_swp, bs as f64, || {
            std::hint::black_box(native.responses(x, bs).unwrap());
        });
        let tb = rb.throughput_per_sec();
        record(&mut report, rb);
        let speedup = tb / t1.max(1e-9);
        println!("  -> batch {bs}: bit-sliced kernel speedup {speedup:.1}x");
        speedup_at.push((bs, speedup));
    }
    let bitsliced_speedup = speedup_at
        .iter()
        .find(|(bs, _)| *bs >= 256)
        .map(|&(bs, s)| {
            println!(
                "acceptance: {s:.1}x at batch {bs} (target ≥ 4x single-thread) {}",
                if s >= 4.0 { "✓" } else { "✗ BELOW TARGET" }
            );
            s
        });

    // == fused sweep: PR-1 encode+transpose+kernel vs the fused slice path ==
    // The PR-1 batch path materialized one BitVec per sample
    // (`encode_into`) and transposed the tile into sample slices inside
    // `responses_batch`; the fused path encodes straight into the slice
    // layout. Same model, same rows, bit-exact outputs — pure overhead
    // delta.
    println!("\n== fused sweep: encode+transpose+kernel vs fused slices, batch 256 ==");
    let bs = 256usize;
    let x = &ds.test_x[..bs * f];
    let flat = FlatModel::compile(&model);
    let bits = model.encoded_bits();
    let mut enc_bufs: Vec<BitVec> = (0..bs).map(|_| BitVec::zeros(bits)).collect();
    let mut pr1_scratch = FlatBatchScratch::default();
    let mut resp = vec![0i32; bs * m];
    let r_pr1 = bench_fn("pr1 encode+transpose ×256", w_swp, i_swp, bs as f64, || {
        for i in 0..bs {
            enc.encode_into(&x[i * f..(i + 1) * f], &mut enc_bufs[i]);
        }
        flat.responses_batch(&enc_bufs, &mut pr1_scratch, &mut resp);
        std::hint::black_box(&resp);
    });
    let t_pr1 = r_pr1.throughput_per_sec();
    record(&mut report, r_pr1);
    let mut fused_scratch = FlatBatchScratch::default();
    let r_fused = bench_fn("fused slice path   ×256", w_swp, i_swp, bs as f64, || {
        flat.responses_batch_fused(&enc, x, bs, &mut fused_scratch, &mut resp);
        std::hint::black_box(&resp);
    });
    let t_fused = r_fused.throughput_per_sec();
    record(&mut report, r_fused);
    let fused_speedup = t_fused / t_pr1.max(1e-9);
    println!(
        "acceptance: fused {fused_speedup:.2}x vs PR-1 sequence at batch {bs} (target ≥ 1.5x) {}",
        if fused_speedup >= 1.5 { "✓" } else { "✗ BELOW TARGET" }
    );

    // == simd sweep: dispatched kernel vs forced scalar, batch 256 ==
    // Same model, same rows, same fused entry point — only the per-tile
    // kernel differs (dispatch is resolved once at FlatModel compile and
    // carried by the model, so `flat` above already runs the dispatched
    // tier; this stage re-measures with the kernel forced to scalar).
    // On scalar-only hosts the ratio is ~1.0 by construction, so the
    // ≥ 1.3x gate only arms when ULEEN_GATE_SIMD is set AND the
    // dispatched tier is AVX2 (the CI runner class we can vouch for).
    let kernel_path = flat.kernel_path();
    println!(
        "\n== simd sweep: dispatched kernel ({}) vs forced scalar, batch {bs} ==",
        kernel_path.label()
    );
    let flat_scalar = FlatModel::compile_with_kernel(&model, KernelPath::Scalar);
    let mut scalar_scratch = FlatBatchScratch::default();
    let mut resp_scalar = vec![0i32; bs * m];
    let r_scalar = bench_fn("forced scalar      ×256", w_swp, i_swp, bs as f64, || {
        flat_scalar.responses_batch_fused(&enc, x, bs, &mut scalar_scratch, &mut resp_scalar);
        std::hint::black_box(&resp_scalar);
    });
    let t_scalar = r_scalar.throughput_per_sec();
    record(&mut report, r_scalar);
    // bit-exactness gate: one fresh pass through each kernel, compared
    // element-wise — a SIMD divergence dies here in the CI smoke bench
    flat.responses_batch_fused(&enc, x, bs, &mut fused_scratch, &mut resp);
    flat_scalar.responses_batch_fused(&enc, x, bs, &mut scalar_scratch, &mut resp_scalar);
    assert_eq!(
        resp, resp_scalar,
        "dispatched kernel ({}) must be bit-exact with forced scalar",
        kernel_path.label()
    );
    let simd_speedup = t_fused / t_scalar.max(1e-9);
    let simd_gated =
        std::env::var_os("ULEEN_GATE_SIMD").is_some() && kernel_path == KernelPath::Avx2;
    println!(
        "acceptance: {} {simd_speedup:.2}x vs scalar at batch {bs}, bit-exact ✓ \
         (≥ 1.3x gate {}) {}",
        kernel_path.label(),
        if simd_gated { "ARMED" } else { "off" },
        if simd_speedup >= 1.3 {
            "✓"
        } else if kernel_path == KernelPath::Scalar {
            "(scalar host — ratio is 1x by construction)"
        } else {
            "✗ BELOW TARGET"
        }
    );
    if simd_gated {
        assert!(
            simd_speedup >= 1.3,
            "AVX2 kernel regressed below the 1.3x gate: {simd_speedup:.2}x at batch {bs}"
        );
    }

    // == mem-plane sweep: packed class-mask planes vs forced u32, and
    // prefetch on/off, batch 256 (PR-10) ==
    // Widths are forced through CompileOptions — not read from the
    // environment — so the sweep measures the same pair of layouts on
    // every runner regardless of ULEEN_MASK_WIDTH/ULEEN_NO_PREFETCH.
    let mem_width = MaskWidth::required_for(m);
    println!(
        "\n== mem-plane sweep: {} planes vs forced u32, prefetch on/off, batch {bs} ==",
        mem_width.label()
    );
    let flat_packed = FlatModel::compile_with(
        &model,
        CompileOptions { mask_width: Some(mem_width), prefetch: Some(true), ..Default::default() },
    );
    let flat_u32 = FlatModel::compile_with(
        &model,
        CompileOptions {
            mask_width: Some(MaskWidth::U32),
            prefetch: Some(true),
            ..Default::default()
        },
    );
    let flat_nopf = FlatModel::compile_with(
        &model,
        CompileOptions { mask_width: Some(mem_width), prefetch: Some(false), ..Default::default() },
    );
    let mem_model_bytes = flat_packed.model_bytes();
    let mem_model_bytes_u32 = flat_u32.model_bytes();
    let mem_baseline_bytes = flat_packed.baseline_u32_bytes();
    println!(
        "resident model plane: {} B packed ({}) vs {} B forced-u32 vs {} B pre-v10 layout",
        mem_model_bytes,
        mem_width.label(),
        mem_model_bytes_u32,
        mem_baseline_bytes
    );
    // bytes-touched-per-sample estimate: every (filter, hash) probe is
    // one random mask-word load; the CSR stream reads each set input
    // bit's record run, ~half the encoded bits set on average
    let mem_bytes_touched: f64 = flat_packed
        .submodels
        .iter()
        .map(|sm| {
            let nf = sm.cfg.num_filters() as f64;
            let n_in = sm.cfg.inputs_per_filter as f64;
            let k = sm.k as f64;
            nf * k * mem_width.bytes() as f64 + 0.5 * nf * n_in * (k + 1.0) * 8.0
        })
        .sum();
    println!("bytes touched / sample (probe + ~half the CSR stream): ~{mem_bytes_touched:.0} B");
    let mut packed_scratch = FlatBatchScratch::default();
    let mut resp_packed = vec![0i32; bs * m];
    let r_packed = bench_fn(
        &format!("packed {} masks  ×256", mem_width.label()),
        w_swp,
        i_swp,
        bs as f64,
        || {
            flat_packed.responses_batch_fused(&enc, x, bs, &mut packed_scratch, &mut resp_packed);
            std::hint::black_box(&resp_packed);
        },
    );
    let t_packed = r_packed.throughput_per_sec();
    record(&mut report, r_packed);
    let mut u32_scratch = FlatBatchScratch::default();
    let mut resp_u32 = vec![0i32; bs * m];
    let r_u32 = bench_fn("forced u32 masks   ×256", w_swp, i_swp, bs as f64, || {
        flat_u32.responses_batch_fused(&enc, x, bs, &mut u32_scratch, &mut resp_u32);
        std::hint::black_box(&resp_u32);
    });
    let t_u32 = r_u32.throughput_per_sec();
    record(&mut report, r_u32);
    let mut nopf_scratch = FlatBatchScratch::default();
    let mut resp_nopf = vec![0i32; bs * m];
    let r_nopf = bench_fn("prefetch off       ×256", w_swp, i_swp, bs as f64, || {
        flat_nopf.responses_batch_fused(&enc, x, bs, &mut nopf_scratch, &mut resp_nopf);
        std::hint::black_box(&resp_nopf);
    });
    let t_nopf = r_nopf.throughput_per_sec();
    record(&mut report, r_nopf);
    // bit-exactness across the whole matrix, against the scalar/u32
    // numbers already computed by the simd sweep above
    flat_packed.responses_batch_fused(&enc, x, bs, &mut packed_scratch, &mut resp_packed);
    flat_u32.responses_batch_fused(&enc, x, bs, &mut u32_scratch, &mut resp_u32);
    flat_nopf.responses_batch_fused(&enc, x, bs, &mut nopf_scratch, &mut resp_nopf);
    assert_eq!(resp_packed, resp_scalar, "packed planes must be bit-exact with scalar/u32");
    assert_eq!(resp_u32, resp_scalar, "forced-u32 planes must be bit-exact with scalar/u32");
    assert_eq!(resp_nopf, resp_scalar, "prefetch must never change a response bit");
    // ALWAYS-ON exact assert (ISSUE 10 acceptance): a 10-class model's
    // mask plane is exactly HALF its u32 size
    assert_eq!(mem_width, MaskWidth::U16, "the MNIST shape serves 10 classes");
    assert_eq!(
        flat_packed.mask_plane_bytes() * 2,
        flat_u32.mask_plane_bytes(),
        "a 10-class mask plane must be exactly half its u32 size"
    );
    assert!(
        mem_model_bytes < mem_baseline_bytes,
        "the arena layout must shrink vs the pre-v10 resident bytes"
    );
    let packed_speedup = t_packed / t_u32.max(1e-9);
    let prefetch_speedup = t_packed / t_nopf.max(1e-9);
    let memplane_gated = std::env::var_os("ULEEN_GATE_MEMPLANE").is_some();
    println!(
        "acceptance: packed {packed_speedup:.2}x vs u32, prefetch {prefetch_speedup:.2}x vs off, \
         half-size plane ✓, bit-exact ✓ (≥ 1.15x gate {})",
        if memplane_gated { "ARMED" } else { "off" }
    );
    if memplane_gated {
        assert!(
            packed_speedup >= 1.15,
            "packed planes regressed below the 1.15x gate: {packed_speedup:.2}x at batch {bs}"
        );
    }

    // == alloc gate: steady-state allocations on the fused native path ==
    // The write-into plane contract says a warm NativeEngine serves
    // responses_into/classify_into with ZERO heap allocations. Counted
    // per-thread by util::alloc_witness when built with
    // `--features alloc-witness` (the CI smoke invocation), asserted to
    // be exactly zero — an allocation sneaking back into the hot path
    // fails the smoke bench, not a nightly.
    #[cfg(feature = "alloc-witness")]
    let allocs_per_batch: Option<f64> = {
        use uleen::util::alloc_witness::Witness;
        println!("\n== alloc gate: fused native write-into path, batch {bs} ==");
        let mut resp_plane = vec![0f32; bs * m];
        let mut pred_plane = vec![0usize; bs];
        for _ in 0..2 {
            native.responses_into(x, bs, &mut resp_plane)?;
            native.classify_into(x, bs, &mut pred_plane)?;
        }
        let gate_calls = 16u64;
        let w = Witness::begin();
        for _ in 0..gate_calls {
            native.responses_into(x, bs, &mut resp_plane)?;
            native.classify_into(x, bs, &mut pred_plane)?;
        }
        std::hint::black_box((&resp_plane, &pred_plane));
        let per_batch = w.allocations() as f64 / (2 * gate_calls) as f64;
        println!(
            "acceptance: {per_batch:.3} allocs/batch over {} warm calls (target = 0) {}",
            2 * gate_calls,
            if per_batch == 0.0 { "✓" } else { "✗ ALLOCATION REGRESSION" }
        );
        assert_eq!(
            w.allocations(),
            0,
            "steady-state allocations crept back into the fused native path"
        );
        Some(per_batch)
    };
    #[cfg(not(feature = "alloc-witness"))]
    let allocs_per_batch: Option<f64> = {
        println!(
            "(skip alloc gate: rebuild with --features alloc-witness to count \
             allocs/batch on the fused native path)"
        );
        None
    };

    // == request-plane alloc gate: submit→complete through the Server ==
    // The PR-8 contract on top of the engine gate above: one request
    // costs ZERO steady-state heap allocations on the caller thread —
    // features copy straight into their slab arena slot, the ring
    // batcher reuses per-worker buffers, and completions ride the slim
    // (id, pred) tuple. Counted per-thread, so the worker-side mpsc
    // node alloc (the documented std-channel exception) cannot mask a
    // caller-side regression — and vice versa.
    #[cfg(feature = "alloc-witness")]
    let allocs_per_request: Option<f64> = {
        use uleen::util::alloc_witness::Witness;
        println!("\n== request-plane alloc gate: submit→complete, waves of {bs} ==");
        let mq = model.clone();
        let srv = Server::start(
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 64,
                    max_wait: std::time::Duration::from_micros(200),
                    capacity: 4096,
                },
                workers: 1,
            },
            move |_| Ok(Box::new(NativeEngine::new(mq.clone())) as Box<dyn InferenceEngine>),
        )?;
        let (tx, rx) = std::sync::mpsc::channel();
        let mut wave = |witnessed: bool| -> anyhow::Result<u64> {
            let w = witnessed.then(Witness::begin);
            for i in 0..bs {
                loop {
                    match srv.submit(ds.test_row(i), tx.clone()) {
                        Ok(_) => break,
                        Err(uleen::coordinator::batcher::SubmitError::Full) => {
                            std::thread::sleep(std::time::Duration::from_micros(20))
                        }
                        Err(e) => anyhow::bail!("submit: {e:?}"),
                    }
                }
            }
            for _ in 0..bs {
                let (_id, p) = rx.recv_timeout(std::time::Duration::from_secs(10))?;
                std::hint::black_box(p);
            }
            Ok(w.map(|w| w.allocations()).unwrap_or(0))
        };
        // Warm waves: the first Sender clone upgrades the channel flavor
        // and every reusable buffer reaches its high-water mark.
        for _ in 0..3 {
            wave(false)?;
        }
        let gate_waves = 4u64;
        let mut allocs = 0u64;
        for _ in 0..gate_waves {
            allocs += wave(true)?;
        }
        let per_request = allocs as f64 / (gate_waves * bs as u64) as f64;
        println!(
            "acceptance: {per_request:.4} allocs/request over {} requests (target = 0) {}",
            gate_waves * bs as u64,
            if allocs == 0 { "✓" } else { "✗ ALLOCATION REGRESSION" }
        );
        assert_eq!(
            allocs, 0,
            "steady-state allocations crept back into the submit→complete request plane"
        );
        srv.shutdown();
        Some(per_request)
    };
    #[cfg(not(feature = "alloc-witness"))]
    let allocs_per_request: Option<f64> = {
        println!(
            "(skip request-plane alloc gate: rebuild with --features alloc-witness \
             to count allocs/request through the serving plane)"
        );
        None
    };

    // == shard sweep: the fused kernel fanned across the persistent pool ==
    println!("\n== shard sweep: ShardedEngine.classify, batch 1024 ==");
    let bs = 1024usize.min(ds.n_test());
    let x = &ds.test_x[..bs * f];
    let shard_counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4, 8] };
    let mut pool_pinned_max = 0usize;
    for &shards in shard_counts {
        let mut sh = ShardedEngine::new(model.clone(), shards);
        let r = bench_fn(&format!("shards={shards} ×{bs}"), w_swp, i_swp, bs as f64, || {
            std::hint::black_box(sh.classify(x, bs).unwrap());
        });
        record(&mut report, r);
        assert_eq!(
            sh.threads_spawned(),
            shards,
            "persistent pool must spawn exactly once"
        );
        // topology witness: how many pool workers landed a core pin
        // (0 on non-Linux hosts or under ULEEN_NO_PIN — reported, not gated)
        let pinned = sh.workers_pinned();
        pool_pinned_max = pool_pinned_max.max(pinned);
        println!("  (shards={shards}: {pinned}/{shards} workers pinned to distinct cores)");
    }

    // == cascade sweep: the ULN-S/M/L zoo through the fused batch kernel ==
    // Tier-pinned Fast-only vs the batched confidence cascade vs pinned
    // Accurate-only, all at batch 256 — the cascade should sit between
    // the two pins (most rows resolve on the small model; thin-margin
    // rows pay for the bigger tiers).
    println!("\n== cascade sweep: fast-only vs batched cascade vs accurate-only, batch 256 ==");
    let mut zoo_models = Vec::new();
    for (ipf, epf, bits) in uleen::train::oneshot::ZOO_PRESET_SHAPES {
        let (zm, _) = uleen::train::oneshot::train_oneshot(
            &ds,
            &uleen::train::oneshot::OneShotConfig {
                inputs_per_filter: ipf,
                entries_per_filter: epf,
                therm_bits: bits,
                ..Default::default()
            },
        );
        zoo_models.push(zm);
    }
    let mut router = ModelRouter::from_models(&zoo_models);
    let bs = 256usize;
    let zx = &ds.test_x[..bs * f];
    let r_fast = bench_fn("zoo fast-only ×256", w_swp, i_swp, bs as f64, || {
        std::hint::black_box(router.classify_batch(zx, bs, Tier::Fast).unwrap());
    });
    let t_zoo_fast = r_fast.throughput_per_sec();
    record(&mut report, r_fast);
    let r_casc = bench_fn("zoo cascade   ×256", w_swp, i_swp, bs as f64, || {
        std::hint::black_box(router.classify_cascade_batch(zx, bs).unwrap());
    });
    let t_zoo_cascade = r_casc.throughput_per_sec();
    record(&mut report, r_casc);
    let r_acc = bench_fn("zoo accurate  ×256", w_swp, i_swp, bs as f64, || {
        std::hint::black_box(router.classify_batch(zx, bs, Tier::Accurate).unwrap());
    });
    let t_zoo_accurate = r_acc.throughput_per_sec();
    record(&mut report, r_acc);
    // fast-path fraction from one counted run (bench runs polluted stats)
    router.stats = Default::default();
    router.classify_cascade_batch(zx, bs).unwrap();
    let zoo_fast_path = router.fast_path_fraction();
    println!(
        "  -> cascade {:.0} inf/s between fast-only {:.0} and accurate-only {:.0}; \
         fast-path fraction {:.2}",
        t_zoo_cascade, t_zoo_fast, t_zoo_accurate, zoo_fast_path
    );

    // == cascade×shard sweep: the batched cascade fanned across the pool ==
    // The two scaling axes composed: ShardedRouterEngine splits the batch
    // into contiguous row ranges, each range runs the full cascade on a
    // persistent pool worker against Arc-shared tiers, and per-tier
    // counters merge deterministically. Runs under --smoke so CI fails
    // fast on a counter-merge or sharing regression.
    println!("\n== cascade×shard sweep: sharded batched cascade, batch {bs} ==");
    let shared_tiers: Vec<SharedModel> = zoo_models
        .iter()
        .map(|m| SharedModel::compile(m.clone()))
        .collect();
    let zoo_shard_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut shard_sweep: Vec<(usize, f64)> = Vec::new();
    for &shards in zoo_shard_counts {
        let mut eng = ShardedRouterEngine::from_shared(shared_tiers.clone(), 0.05, shards);
        let r = bench_fn(
            &format!("zoo cascade shards={shards} ×{bs}"),
            w_swp,
            i_swp,
            bs as f64,
            || {
                std::hint::black_box(eng.classify(zx, bs).unwrap());
            },
        );
        shard_sweep.push((shards, r.throughput_per_sec()));
        record(&mut report, r);
        pool_pinned_max = pool_pinned_max.max(eng.workers_pinned());
        // Zero per-worker model clones, witnessed: exactly one Arc handle
        // here + one in the engine's tier list + one per pool worker.
        for (t_idx, t) in shared_tiers.iter().enumerate() {
            assert_eq!(
                std::sync::Arc::strong_count(t.model()),
                2 + shards,
                "tier {t_idx}: per-worker model clones detected at shards={shards}"
            );
        }
    }
    // Merge gate: a sharded run's predictions AND pool-merged per-tier
    // counters must be bit-exact with the single-router cascade. A
    // merge-order regression in counter merging dies HERE, in the CI
    // smoke bench, not in a nightly.
    let mut gate = ShardedRouterEngine::from_shared(shared_tiers.clone(), 0.05, 7);
    let gate_preds = gate.classify(zx, bs).unwrap();
    router.stats = Default::default();
    let want_preds = router.classify_cascade_batch(zx, bs).unwrap();
    assert_eq!(
        gate_preds, want_preds,
        "cascade×shard predictions must match the single-router cascade"
    );
    let gate_merged = gate.merged_stats();
    assert_eq!(
        gate_merged.served, router.stats.served,
        "pool-merged served counters must be bit-exact with the single-router cascade"
    );
    assert_eq!(
        gate_merged.escalations_from, router.stats.escalations_from,
        "pool-merged escalation counters must be bit-exact with the single-router cascade"
    );
    println!(
        "  -> merge gate: predictions + per-tier counters bit-exact across 7 shards ✓ \
         (zero per-worker model clones, Arc-witnessed)"
    );

    // engine-level batch API (what the coordinator calls)
    let flat_x: Vec<f32> = ds.test_x[..n * f].to_vec();
    let r = bench_fn("NativeEngine.classify batch", w_hot, i_hot, n as f64, || {
        std::hint::black_box(native.classify(&flat_x, n).unwrap());
    });
    println!();
    record(&mut report, r);

    // == http loopback sweep: the serving edge over real sockets ==
    // Client threads drive POST /v1/classify through HttpFrontend against
    // the same model; every served prediction is checked against the
    // engine's local output, so a wire-format or routing regression dies
    // here in the CI smoke bench.
    println!("\n== http loopback sweep: 4 socket clients × POST /v1/classify ==");
    let http_clients = 4usize;
    let http_reqs = if smoke { 5usize } else { 40 };
    let http_rows = 16usize;
    let http_want = std::sync::Arc::new(native.classify(&ds.test_x, ds.n_test())?);
    let dsa = std::sync::Arc::new(ds.clone());
    let mc = model.clone();
    let http_server = std::sync::Arc::new(Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 64,
                max_wait: std::time::Duration::from_micros(200),
                capacity: 8192,
            },
            workers: 2,
        },
        move |_| Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>),
    )?);
    let frontend = HttpFrontend::start("127.0.0.1:0", http_server.clone(), HttpConfig::default())?;
    let http_addr = frontend.local_addr().to_string();
    let http_t0 = std::time::Instant::now();
    let mut http_handles = Vec::new();
    for c in 0..http_clients {
        let (addr, dsa, want) = (http_addr.clone(), dsa.clone(), http_want.clone());
        http_handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut mismatches = 0usize;
            for r in 0..http_reqs {
                let start = (c * 53 + r * http_rows) % (dsa.n_test() - http_rows);
                let mut j = Json::obj();
                j.set(
                    "rows",
                    Json::Arr(
                        (start..start + http_rows)
                            .map(|i| {
                                Json::Arr(
                                    dsa.test_row(i).iter().map(|&v| Json::Num(v as f64)).collect(),
                                )
                            })
                            .collect(),
                    ),
                );
                let resp = client::request(&addr, "POST", "/v1/classify", None, Some(&j.to_string()))?;
                anyhow::ensure!(resp.status == 200, "HTTP {}: {}", resp.status, resp.body);
                let got: Vec<usize> = Json::parse(&resp.body)
                    .map_err(anyhow::Error::msg)?
                    .get("predictions")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("no predictions in {}", resp.body))?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(-1.0) as usize)
                    .collect();
                mismatches += got
                    .iter()
                    .zip(&want[start..start + http_rows])
                    .filter(|(a, b)| a != b)
                    .count();
            }
            Ok(mismatches)
        }));
    }
    let mut http_mismatches = 0usize;
    for h in http_handles {
        http_mismatches += h.join().expect("http client thread panicked")?;
    }
    let http_elapsed = http_t0.elapsed().as_secs_f64();
    let http_rps = (http_clients * http_reqs) as f64 / http_elapsed;
    frontend.shutdown();
    std::sync::Arc::try_unwrap(http_server)
        .ok()
        .expect("server handle leaked")
        .shutdown();
    assert_eq!(
        http_mismatches, 0,
        "HTTP-served predictions must match the local engine"
    );
    println!(
        "  {} requests × {http_rows} rows over {http_clients} clients: {http_rps:.0} req/s, \
         agreement exact ✓",
        http_clients * http_reqs
    );

    // PJRT engine comparison (AOT graph through XLA)
    #[cfg(feature = "pjrt")]
    {
        let hlo = uleen::bench::artifacts_dir().join("uln_s_b16.hlo.txt");
        if hlo.exists() {
            let mut pjrt = PjrtEngine::load(&hlo, 16, 784)?;
            let r = bench_fn("PjrtEngine.classify batch", 2, 10, n as f64, || {
                std::hint::black_box(pjrt.classify(&flat_x, n).unwrap());
            });
            record(&mut report, r);
            println!(
                "native/pjrt speed ratio: {:.1}x (native bit-packed tables vs XLA f32 gathers)",
                report.last().unwrap().1.mean_ns / (n as f64) / (1e9 / native_ips)
            );
        } else {
            println!("(skip PJRT: {} missing — run `make artifacts`)", hlo.display());
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = native_ips;
        println!("(skip PJRT: built without --features pjrt)");
    }

    // == autopilot sweep: bursty traffic vs a p99 SLO, static vs steered ==
    // Bursts of 8 rows against a 32-row micro-batcher: the batch never
    // fills, so every request waits out the full dwell and the static
    // config (margin 0.9, dwell 5 ms) structurally misses a 2 ms p99 —
    // no load spike needed, the miss is deterministic. The same traffic
    // with `--target-p99-ms`-style steering attached lets the AIMD loop
    // cut dwell (and margin) until the window p99 sits inside the
    // hysteresis band under the target. p99 is measured CLIENT-side
    // (submit → completion) over the post-warmup rounds, i.e. the number
    // a caller would see, not the server's own histogram.
    println!("\n== autopilot sweep: bursty zoo traffic, static knobs vs AIMD steering ==");
    let ap_rounds = if smoke { 150usize } else { 400 };
    let ap_burst = 8usize;
    let ap_target_ms = 2.0f64;
    let ap_static_margin = 0.9f32;
    let ap_static_dwell = std::time::Duration::from_millis(5);
    // -> (client p99 ms over post-warmup rounds, final margin, final dwell µs)
    let run_pass = |steered: bool| -> anyhow::Result<(f64, f32, f64)> {
        let srv = Server::start_zoo_shared(
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 32,
                    max_wait: ap_static_dwell,
                    capacity: 4096,
                },
                workers: 1,
            },
            shared_tiers.clone(),
            ap_static_margin,
        )?;
        let pilot = steered.then(|| {
            Autopilot::start(
                AutopilotConfig { target_p99_ms: ap_target_ms, ..Default::default() },
                srv.metrics.clone(),
                srv.margin_knob(),
                srv.dwell_knob(),
            )
        });
        let (tx, rx) = std::sync::mpsc::channel();
        let warmup_rounds = ap_rounds * 2 / 5;
        let mut lats_us: Vec<f64> = Vec::with_capacity((ap_rounds - warmup_rounds) * ap_burst);
        let mut sent: std::collections::HashMap<u64, std::time::Instant> =
            std::collections::HashMap::with_capacity(ap_burst);
        for round in 0..ap_rounds {
            for i in 0..ap_burst {
                let row = ds.test_row((round * ap_burst + i) % ds.n_test());
                let t0 = std::time::Instant::now();
                let id = loop {
                    match srv.submit(row, tx.clone()) {
                        Ok(id) => break id,
                        Err(uleen::coordinator::batcher::SubmitError::Full) => {
                            std::thread::sleep(std::time::Duration::from_micros(50))
                        }
                        Err(e) => anyhow::bail!("submit: {e:?}"),
                    }
                };
                sent.insert(id, t0);
            }
            for _ in 0..ap_burst {
                let (id, _pred) = rx.recv_timeout(std::time::Duration::from_secs(10))?;
                let t0 = sent.remove(&id).expect("completion for an unknown id");
                if round >= warmup_rounds {
                    lats_us.push(t0.elapsed().as_secs_f64() * 1e6);
                }
            }
        }
        let final_margin = srv.margin_knob().map(|k| k.get()).unwrap_or(f32::NAN);
        let final_dwell_us = srv.dwell_knob().get().as_secs_f64() * 1e6;
        if let Some(p) = pilot {
            p.stop();
        }
        srv.shutdown();
        Ok((percentile(&mut lats_us, 0.99) / 1e3, final_margin, final_dwell_us))
    };
    let (ap_static_p99_ms, ap_static_final_margin, ap_static_final_dwell_us) = run_pass(false)?;
    let (ap_auto_p99_ms, ap_final_margin, ap_final_dwell_us) = run_pass(true)?;
    // With no autopilot attached the knobs must not move — the flag-off
    // path stays bit-identical to a static server.
    assert_eq!(
        ap_static_final_margin, ap_static_margin,
        "margin knob moved on the unsteered pass"
    );
    assert_eq!(
        ap_static_final_dwell_us,
        ap_static_dwell.as_secs_f64() * 1e6,
        "dwell knob moved on the unsteered pass"
    );
    let ap_gated = std::env::var_os("ULEEN_GATE_AUTOPILOT").is_some();
    println!(
        "  static:    p99 {ap_static_p99_ms:.2} ms  (margin {ap_static_final_margin:.2}, \
         dwell {ap_static_final_dwell_us:.0} µs — frozen)"
    );
    println!(
        "  autopilot: p99 {ap_auto_p99_ms:.2} ms  (margin {ap_final_margin:.3}, \
         dwell {ap_final_dwell_us:.0} µs)  target {ap_target_ms} ms"
    );
    let ap_holds = ap_auto_p99_ms <= ap_target_ms && ap_static_p99_ms > ap_target_ms;
    let ap_knobs_moved = ap_final_margin < ap_static_margin
        && ap_final_dwell_us < ap_static_dwell.as_secs_f64() * 1e6;
    println!(
        "acceptance: autopilot holds the p99 target the static config misses, \
         both knobs moved (gate {}) {}",
        if ap_gated { "ARMED" } else { "off" },
        if ap_holds && ap_knobs_moved { "✓" } else { "✗ TARGET MISSED" }
    );
    if ap_gated {
        assert!(
            ap_static_p99_ms > ap_target_ms,
            "the static config was supposed to miss the {ap_target_ms} ms target \
             (got {ap_static_p99_ms:.2} ms) — the scenario no longer stresses the dwell"
        );
        assert!(
            ap_auto_p99_ms <= ap_target_ms,
            "autopilot failed to hold p99 <= {ap_target_ms} ms (got {ap_auto_p99_ms:.2} ms)"
        );
        assert!(
            ap_knobs_moved,
            "autopilot held the target without moving both knobs \
             (margin {ap_final_margin}, dwell {ap_final_dwell_us} µs)"
        );
    }

    // == machine-readable trajectory (ROADMAP follow-up d) ==
    if json_out {
        let mut stages = Json::obj();
        for (name, r) in &report {
            let mut o = Json::obj();
            o.set("ns_per_sample", Json::Num(r.mean_ns / r.items_per_iter.max(1.0)));
            o.set("samples_per_sec", Json::Num(r.throughput_per_sec()));
            stages.set(name, o);
        }
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("engine_hot".into()));
        doc.set("model", Json::Str(model.name.clone()));
        doc.set("smoke", Json::Bool(smoke));
        doc.set("stages", stages);
        if let Some(s) = bitsliced_speedup {
            doc.set("bitsliced_speedup_b256", Json::Num(s));
        }
        doc.set("fused_speedup_vs_pr1_b256", Json::Num(fused_speedup));
        doc.set("kernel_path", Json::Str(kernel_path.label().to_string()));
        let mut simd_doc = Json::obj();
        simd_doc
            .set("path", Json::Str(kernel_path.label().to_string()))
            .set("scalar_sps", Json::Num(t_scalar))
            .set("dispatched_sps", Json::Num(t_fused))
            .set("speedup_b256", Json::Num(simd_speedup))
            // asserted above — serialized so the trajectory records that
            // the bit-exactness gate ran, not just that the bench finished
            .set("bit_exact", Json::Bool(true))
            .set("gated", Json::Bool(simd_gated))
            .set("pool_pinned_workers_max", Json::Num(pool_pinned_max as f64));
        doc.set("simd", simd_doc);
        let mut mem_doc = Json::obj();
        mem_doc
            .set("mask_width", Json::Str(mem_width.label().to_string()))
            .set("model_bytes", Json::Num(mem_model_bytes as f64))
            .set("model_bytes_u32", Json::Num(mem_model_bytes_u32 as f64))
            .set("baseline_pre_v10_bytes", Json::Num(mem_baseline_bytes as f64))
            .set("mask_plane_bytes", Json::Num(flat_packed.mask_plane_bytes() as f64))
            .set("mask_plane_bytes_u32", Json::Num(flat_u32.mask_plane_bytes() as f64))
            .set("bytes_touched_per_sample_est", Json::Num(mem_bytes_touched))
            .set("packed_sps", Json::Num(t_packed))
            .set("u32_sps", Json::Num(t_u32))
            .set("prefetch_off_sps", Json::Num(t_nopf))
            .set("packed_speedup_b256", Json::Num(packed_speedup))
            .set("prefetch_speedup_b256", Json::Num(prefetch_speedup))
            // asserted above — serialized so the trajectory records that
            // the half-plane and bit-exactness gates ran
            .set("half_plane_exact", Json::Bool(true))
            .set("bit_exact", Json::Bool(true))
            .set("gated", Json::Bool(memplane_gated));
        doc.set("mem_plane", mem_doc);
        // present iff built with --features alloc-witness; asserted == 0
        // in-bench, so a serialized value records that the gate RAN
        if let Some(apb) = allocs_per_batch {
            doc.set("allocs_per_batch_native_b256", Json::Num(apb));
        }
        // present iff built with --features alloc-witness; asserted == 0
        // in-bench (caller-thread submit→complete waves at batch 256)
        if let Some(apr) = allocs_per_request {
            doc.set("allocs_per_request", Json::Num(apr));
        }
        let mut cascade = Json::obj();
        cascade
            .set("fast_only_sps", Json::Num(t_zoo_fast))
            .set("cascade_sps", Json::Num(t_zoo_cascade))
            .set("accurate_only_sps", Json::Num(t_zoo_accurate))
            .set("fast_path_fraction", Json::Num(zoo_fast_path));
        doc.set("cascade_sweep_b256", cascade);
        let mut shard_doc = Json::obj();
        for (shards, sps) in &shard_sweep {
            shard_doc.set(&format!("shards_{shards}_sps"), Json::Num(*sps));
        }
        // asserted above — serialized so the trajectory records that the
        // gate ran, not just that the bench finished
        shard_doc
            .set("merged_counters_exact", Json::Bool(true))
            .set("zero_model_clones", Json::Bool(true));
        doc.set("cascade_shard_sweep_b256", shard_doc);
        // the autopilot_sweep schema row in EXPERIMENTS.md — the gate
        // asserts the hold when ULEEN_GATE_AUTOPILOT is set; the numbers
        // serialize either way so the trajectory records every run
        let mut ap_doc = Json::obj();
        ap_doc
            .set("target_p99_ms", Json::Num(ap_target_ms))
            .set("achieved_p99_ms_static", Json::Num(ap_static_p99_ms))
            .set("achieved_p99_ms_autopilot", Json::Num(ap_auto_p99_ms))
            .set("final_margin", Json::Num(ap_final_margin as f64))
            .set("final_dwell_us", Json::Num(ap_final_dwell_us))
            .set("gated", Json::Bool(ap_gated));
        doc.set("autopilot_sweep", ap_doc);
        let mut http_doc = Json::obj();
        http_doc
            .set("clients", Json::Num(http_clients as f64))
            .set("requests_per_client", Json::Num(http_reqs as f64))
            .set("rows_per_request", Json::Num(http_rows as f64))
            .set("http_rps", Json::Num(http_rps))
            // asserted above — recorded so the trajectory shows the wire
            // agreement gate ran, not just that the bench finished
            .set("agreement_exact", Json::Bool(http_mismatches == 0));
        doc.set("http_loadtest", http_doc);
        let path = "BENCH_engine_hot.json";
        std::fs::write(path, doc.to_string())?;
        println!("(wrote {path})");
    }
    Ok(())
}
