//! L3 hot-path microbenchmarks (§Perf): encode / gather+hash / lookup /
//! full ensemble inference on the native engine, plus the PJRT engine for
//! comparison when artifacts exist. This is the bench the optimization
//! loop in EXPERIMENTS.md §Perf iterates against.

use uleen::bench::harness::bench_fn;
use uleen::data::synth_mnist;
use uleen::model::ensemble::EnsembleScratch;
use uleen::model::submodel::SubmodelScratch;
use uleen::runtime::{InferenceEngine, NativeEngine, PjrtEngine};

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 64, 256);
    let (model, _) = uleen::bench::load_model("uln_s.uln")?;
    let n = 256usize;
    println!("== engine_hot: native hot-path stages (ULN-S, {n} samples/iter) ==");

    // stage 1: thermometer encode
    let enc = model.encoder.clone();
    let r = bench_fn("encode", 3, 30, n as f64, || {
        for i in 0..n {
            std::hint::black_box(enc.encode(ds.test_row(i)));
        }
    });
    println!("{}", r.summary());

    // stage 2: gather + hash (submodel 0)
    let sm = model.submodels[0].clone();
    let encoded: Vec<_> = (0..n).map(|i| enc.encode(ds.test_row(i))).collect();
    let mut scratch = SubmodelScratch::default();
    let r = bench_fn("gather+hash (SM0)", 3, 30, n as f64, || {
        for e in &encoded {
            sm.gather_keys(e, &mut scratch.keys);
            sm.hash_keys(&scratch.keys, &mut scratch.idxs);
            std::hint::black_box(&scratch.idxs);
        }
    });
    println!("{}", r.summary());

    // stage 3: full submodel responses (lookup included)
    let mut out = vec![0i32; model.num_classes()];
    let r = bench_fn("submodel responses (SM0)", 3, 30, n as f64, || {
        for e in &encoded {
            sm.responses(e, &mut scratch, &mut out);
            std::hint::black_box(&out);
        }
    });
    println!("{}", r.summary());

    // stage 4: end-to-end ensemble predict from raw pixels
    let mut es = EnsembleScratch::default();
    let r = bench_fn("ensemble predict e2e", 3, 30, n as f64, || {
        for i in 0..n {
            std::hint::black_box(model.predict(ds.test_row(i), &mut es));
        }
    });
    println!("{}", r.summary());
    let native_ips = r.throughput_per_sec();

    // engine-level batch API (what the coordinator calls)
    let mut native = NativeEngine::new(model.clone());
    let flat: Vec<f32> = ds.test_x[..n * 784].to_vec();
    let r = bench_fn("NativeEngine.classify batch", 3, 30, n as f64, || {
        std::hint::black_box(native.classify(&flat, n).unwrap());
    });
    println!("{}", r.summary());

    // PJRT engine comparison (AOT graph through XLA)
    let hlo = uleen::bench::artifacts_dir().join("uln_s_b16.hlo.txt");
    if hlo.exists() {
        let mut pjrt = PjrtEngine::load(&hlo, 16, 784)?;
        let r = bench_fn("PjrtEngine.classify batch", 2, 10, n as f64, || {
            std::hint::black_box(pjrt.classify(&flat, n).unwrap());
        });
        println!("{}", r.summary());
        println!(
            "native/pjrt speed ratio: {:.1}x (native bit-packed tables vs XLA f32 gathers)",
            r.mean_ns / (n as f64) / (1e9 / native_ips)
        );
    } else {
        println!("(skip PJRT: {} missing — run `make artifacts`)", hlo.display());
    }
    Ok(())
}
