//! L3 hot-path microbenchmarks (§Perf): encode / gather+hash / lookup /
//! full ensemble inference on the native engine, the bit-sliced batch
//! kernel and the sharded engine, plus the PJRT engine for comparison when
//! built with `--features pjrt` and artifacts exist. This is the bench the
//! optimization loop in EXPERIMENTS.md §Perf iterates against.
//!
//! The headline number is the batch-kernel sweep: per-sample vs bit-sliced
//! throughput at batch ≥ 256 (target: ≥ 4× single-thread), then the shard
//! sweep on top of the batch kernel.

use uleen::bench::harness::bench_fn;
use uleen::data::synth_mnist;
use uleen::model::ensemble::EnsembleScratch;
use uleen::model::submodel::SubmodelScratch;
use uleen::runtime::{InferenceEngine, NativeEngine, ShardedEngine};
#[cfg(feature = "pjrt")]
use uleen::runtime::PjrtEngine;

/// The multi-shot artifact when available, else a one-shot stand-in with
/// the same shape class — the kernel sweeps must run in offline checkouts.
fn load_or_train(ds: &uleen::data::Dataset) -> uleen::model::ensemble::UleenModel {
    match uleen::bench::load_model("uln_s.uln") {
        Ok((model, _)) => model,
        Err(e) => {
            println!("(no artifact: {e} — falling back to a one-shot model)");
            uleen::train::oneshot::train_oneshot(
                ds,
                &uleen::train::oneshot::OneShotConfig {
                    inputs_per_filter: 16,
                    entries_per_filter: 256,
                    therm_bits: 4,
                    ..Default::default()
                },
            )
            .0
        }
    }
}

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 64, 1024);
    let model = load_or_train(&ds);
    let n = 256usize;
    println!("== engine_hot: native hot-path stages ({}, {n} samples/iter) ==", model.name);

    // stage 1: thermometer encode
    let enc = model.encoder.clone();
    let r = bench_fn("encode", 3, 30, n as f64, || {
        for i in 0..n {
            std::hint::black_box(enc.encode(ds.test_row(i)));
        }
    });
    println!("{}", r.summary());

    // stage 2: gather + hash (submodel 0)
    let sm = model.submodels[0].clone();
    let encoded: Vec<_> = (0..n).map(|i| enc.encode(ds.test_row(i))).collect();
    let mut scratch = SubmodelScratch::default();
    let r = bench_fn("gather+hash (SM0)", 3, 30, n as f64, || {
        for e in &encoded {
            sm.gather_keys(e, &mut scratch.keys);
            sm.hash_keys(&scratch.keys, &mut scratch.idxs);
            std::hint::black_box(&scratch.idxs);
        }
    });
    println!("{}", r.summary());

    // stage 3: full submodel responses (lookup included)
    let mut out = vec![0i32; model.num_classes()];
    let r = bench_fn("submodel responses (SM0)", 3, 30, n as f64, || {
        for e in &encoded {
            sm.responses(e, &mut scratch, &mut out);
            std::hint::black_box(&out);
        }
    });
    println!("{}", r.summary());

    // stage 4: end-to-end ensemble predict from raw pixels
    let mut es = EnsembleScratch::default();
    let r = bench_fn("ensemble predict e2e", 3, 30, n as f64, || {
        for i in 0..n {
            std::hint::black_box(model.predict(ds.test_row(i), &mut es));
        }
    });
    println!("{}", r.summary());
    let native_ips = r.throughput_per_sec();

    // == tentpole sweep: per-sample path vs bit-sliced batch kernel ==
    println!("\n== batch sweep: per-sample vs bit-sliced kernel (single thread) ==");
    let f = model.encoder.num_inputs;
    let mut native = NativeEngine::new(model.clone());
    let mut speedup_at = Vec::new();
    for &bs in &[64usize, 256, 1024] {
        let x = &ds.test_x[..bs * f];
        // baseline: the scalar path, forced by n=1 submissions
        let r1 = bench_fn(&format!("per-sample ×{bs}"), 2, 12, bs as f64, || {
            for i in 0..bs {
                std::hint::black_box(
                    native.responses(&x[i * f..(i + 1) * f], 1).unwrap(),
                );
            }
        });
        println!("{}", r1.summary());
        // bit-sliced: one call, 64-sample tiles
        let rb = bench_fn(&format!("bit-sliced  ×{bs}"), 2, 12, bs as f64, || {
            std::hint::black_box(native.responses(x, bs).unwrap());
        });
        println!("{}", rb.summary());
        let speedup = rb.throughput_per_sec() / r1.throughput_per_sec().max(1e-9);
        println!("  -> batch {bs}: bit-sliced kernel speedup {speedup:.1}x");
        speedup_at.push((bs, speedup));
    }
    if let Some(&(bs, s)) = speedup_at.iter().find(|(bs, _)| *bs >= 256) {
        println!(
            "acceptance: {s:.1}x at batch {bs} (target ≥ 4x single-thread) {}",
            if s >= 4.0 { "✓" } else { "✗ BELOW TARGET" }
        );
    }

    // == shard sweep: the batch kernel fanned across threads ==
    println!("\n== shard sweep: ShardedEngine.classify, batch 1024 ==");
    let bs = 1024usize.min(ds.n_test());
    let x = &ds.test_x[..bs * f];
    for &shards in &[1usize, 2, 4, 8] {
        let mut sh = ShardedEngine::new(model.clone(), shards);
        let r = bench_fn(&format!("shards={shards} ×{bs}"), 2, 12, bs as f64, || {
            std::hint::black_box(sh.classify(x, bs).unwrap());
        });
        println!("{}", r.summary());
    }

    // engine-level batch API (what the coordinator calls)
    let flat: Vec<f32> = ds.test_x[..n * f].to_vec();
    let r = bench_fn("NativeEngine.classify batch", 3, 30, n as f64, || {
        std::hint::black_box(native.classify(&flat, n).unwrap());
    });
    println!("\n{}", r.summary());

    // PJRT engine comparison (AOT graph through XLA)
    #[cfg(feature = "pjrt")]
    {
        let hlo = uleen::bench::artifacts_dir().join("uln_s_b16.hlo.txt");
        if hlo.exists() {
            let mut pjrt = PjrtEngine::load(&hlo, 16, 784)?;
            let r = bench_fn("PjrtEngine.classify batch", 2, 10, n as f64, || {
                std::hint::black_box(pjrt.classify(&flat, n).unwrap());
            });
            println!("{}", r.summary());
            println!(
                "native/pjrt speed ratio: {:.1}x (native bit-packed tables vs XLA f32 gathers)",
                r.mean_ns / (n as f64) / (1e9 / native_ips)
            );
        } else {
            println!("(skip PJRT: {} missing — run `make artifacts`)", hlo.display());
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = native_ips;
        println!("(skip PJRT: built without --features pjrt)");
    }
    Ok(())
}
