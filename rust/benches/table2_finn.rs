//! Regenerates **Table II** — ULEEN (FPGA) vs FINN SFC/MFC/LFC: latency,
//! throughput, power, energy per inference (batch=1 and batch=∞), LUTs,
//! BRAM, accuracy. ULEEN rows come from our accelerator generator + FPGA
//! cost model on the trained artifacts; FINN rows from the analytic
//! baseline anchored on published numbers (hw::finn).

use uleen::bench::paper;
use uleen::bench::table::{f1, f2, f3, i0, pct, Table};

fn main() -> anyhow::Result<()> {
    let zoo = paper::load_zoo()?;
    let uleen_rows = paper::uleen_fpga_rows(&zoo);
    let bnn = paper::bnn_accuracies();
    let finn_rows = paper::finn_fpga_rows(bnn.as_ref());

    let mut t = Table::new(
        "Table II — ULEEN vs FINN on FPGA (Zynq Z-7045 class, 112-bit IF)",
        &["Model", "Latency µs", "Xput kIPS", "Power W", "µJ/Inf b=1", "µJ/Inf b=∞", "LUT", "BRAM", "Acc.%"],
    );
    // paper pairs ULN-S↔SFC, ULN-M↔MFC, ULN-L↔LFC
    for (u, f) in uleen_rows.iter().zip(finn_rows.iter()) {
        for r in [u, f] {
            t.row(vec![
                r.name.clone(),
                f2(r.latency_us),
                i0(r.kips),
                f2(r.power_w),
                f3(r.uj_b1),
                f3(r.uj_binf),
                i0(r.luts),
                f1(r.bram),
                pct(r.accuracy),
            ]);
        }
    }
    t.print();

    // headline ratios (paper: 1.4-2.6x latency, 1.2-2.6x throughput,
    // 6.8-8.5x steady-state energy)
    let mut rt = Table::new(
        "Table II ratios — ULEEN improvement over paired FINN model",
        &["Pair", "Latency x", "Xput x", "Energy b=∞ x", "Energy b=1 x"],
    );
    for (u, f) in uleen_rows.iter().zip(finn_rows.iter()) {
        rt.row(vec![
            format!("{} vs {}", u.name, f.name),
            f2(f.latency_us / u.latency_us),
            f2(u.kips / f.kips),
            f2(f.uj_binf / u.uj_binf),
            f2(f.uj_b1 / u.uj_b1),
        ]);
    }
    rt.print();
    Ok(())
}
