//! Regenerates **Table I** — details of the selected ULEEN models: per-
//! submodel config (bits/input, inputs/filter, entries/filter), size in
//! KiB and test accuracy. Accuracy is re-MEASURED here with the native
//! Rust engine on the same SynthMNIST test split (not just read from the
//! training metadata) — the two must agree.

use uleen::bench::table::{f2, pct, Table};
use uleen::data::synth_mnist;

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 8000, 2000);
    let mut table = Table::new(
        "Table I — selected ULEEN models (SynthMNIST; paper Table I geometry)",
        &["Model", "Sub", "Bits/Inp", "Inputs/Filter", "Entries/Filter", "Size (KiB)", "Test Acc.%"],
    );
    for name in ["uln_s", "uln_m", "uln_l"] {
        let (model, meta) = uleen::bench::load_model(&format!("{name}.uln"))?;
        let conf = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features);
        let meta_acc = uleen::bench::meta_accuracy(&meta);
        anyhow::ensure!(
            (conf.accuracy() - meta_acc).abs() < 5e-3,
            "{name}: rust-measured accuracy {:.4} != training metadata {:.4}",
            conf.accuracy(),
            meta_acc
        );
        table.row(vec![
            name.to_uppercase(),
            "Ensemble".into(),
            format!("{}", model.encoder.bits),
            "{}".into(),
            "{}".into(),
            f2(model.size_kib()),
            pct(conf.accuracy()),
        ]);
        let sub_meta = meta.get("submodels").and_then(|j| j.as_arr());
        for (i, sm) in model.submodels.iter().enumerate() {
            let sacc = sub_meta
                .and_then(|arr| arr.get(i))
                .and_then(|j| j.get("accuracy"))
                .and_then(|j| j.as_f64())
                .unwrap_or(f64::NAN);
            table.row(vec![
                String::new(),
                format!("SM{i}"),
                format!("{}", model.encoder.bits),
                format!("{}", sm.cfg.inputs_per_filter),
                format!("{}", sm.cfg.entries_per_filter),
                f2(sm.size_kib()),
                pct(sacc),
            ]);
        }
    }
    table.print();
    println!("(paper reference: ULN-S 16.9 KiB / 96.20%, ULN-M 101 KiB / 97.79%, ULN-L 262 KiB / 98.46% on real MNIST)");
    Ok(())
}
