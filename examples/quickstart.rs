//! Quickstart — train a ULEEN model from scratch in pure Rust (one-shot
//! rule), evaluate it, prune it, save/load it, and size its hardware.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed: everything here runs on the synthetic datasets
//! generated in-process.

use uleen::data::synth_uci::{synth_uci, uci_spec};
use uleen::hw::arch::{AcceleratorInstance, Target};
use uleen::model::uln_format;
use uleen::train::oneshot::{train_oneshot, OneShotConfig};
use uleen::train::prune::prune_model;
use uleen::util::json::Json;

fn main() -> anyhow::Result<()> {
    // 1. a dataset (synthetic stand-in for UCI Vowel — 10 features, 11 classes)
    let ds = synth_uci(2024, uci_spec("vowel").unwrap());
    println!("dataset: {} ({} train / {} test, {} classes)",
        ds.name, ds.n_train(), ds.n_test(), ds.num_classes);

    // 2. one-shot training: counting Bloom filters + bleaching
    let cfg = OneShotConfig {
        inputs_per_filter: 10,
        entries_per_filter: 128,
        therm_bits: 6,
        ..Default::default()
    };
    let (mut model, report) = train_oneshot(&ds, &cfg);
    let acc = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    println!("one-shot: bleach={} test_acc={:.4} size={:.2} KiB",
        report.bleach, acc, model.size_kib());

    // 3. prune 30% of RAM nodes per discriminator (correlation-ranked)
    prune_model(&mut model, &ds, 0.3);
    let acc_pruned = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    println!("pruned 30%: test_acc={:.4} size={:.2} KiB", acc_pruned, model.size_kib());

    // 4. save / reload through the .uln interchange format
    let path = std::env::temp_dir().join("quickstart_vowel.uln");
    let mut meta = Json::obj();
    meta.set("name", Json::Str("quickstart_vowel".into()))
        .set("test_accuracy", Json::Num(acc_pruned));
    uln_format::save(&model, &meta, &path)?;
    let (reloaded, _) = uln_format::load(&path)?;
    let acc_reload = reloaded.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
    assert_eq!(acc_pruned, acc_reload, "save/load must be lossless");
    println!("saved + reloaded: {} (accuracy identical)", path.display());

    // 5. size the hardware for both targets
    for target in [Target::Fpga, Target::Asic] {
        let mut inst = AcceleratorInstance::generate(&reloaded, target);
        match target {
            Target::Fpga => {
                let r = uleen::hw::fpga::implement(&mut inst);
                println!("FPGA: {} LUTs, {:.1} MHz, {:.0} kIPS, {:.3} µJ/inf",
                    r.luts, r.freq_mhz, r.throughput_kips, r.uj_per_inf_steady);
            }
            Target::Asic => {
                let r = uleen::hw::asic::implement(&inst);
                println!("ASIC: {:.2} mm², {:.0} kIPS, {:.1} nJ/inf",
                    r.area_mm2, r.throughput_kips, r.nj_per_inf);
            }
        }
    }
    Ok(())
}
