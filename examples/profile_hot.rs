fn main() -> anyhow::Result<()> {
    use uleen::runtime::{InferenceEngine, NativeEngine};
    let (model, _) = uleen::model::uln_format::load(std::path::Path::new("artifacts/uln_s.uln"))?;
    let ds = uleen::data::synth_mnist(2024, 64, 256);
    let mut native = NativeEngine::new(model);
    let mut acc = 0usize;
    for _ in 0..200 {
        acc += native.classify(&ds.test_x, 256)?.iter().sum::<usize>();
    }
    println!("{acc}");
    Ok(())
}
