//! Hardware co-design sweep — the accuracy/energy/area interplay the paper
//! highlights in §V-D ("ULEEN establishes an interplay between accuracy,
//! efficiency, and area, which can be explored depending on the
//! application").
//!
//!     cargo run --release --example hw_codesign_sweep
//!
//! Trains a grid of one-shot models on SynthMNIST, sizes an ASIC + FPGA
//! instance for each, and prints the co-design frontier: for every
//! accuracy level, the cheapest design that reaches it.

use uleen::bench::table::{f1, f2, i0, pct, Table};
use uleen::data::synth_mnist;
use uleen::hw::arch::{AcceleratorInstance, Target};
use uleen::train::oneshot::{train_oneshot, OneShotConfig};

fn main() -> anyhow::Result<()> {
    let ds = synth_mnist(2024, 4000, 1000);
    let mut t = Table::new(
        "HW co-design sweep (one-shot models on SynthMNIST)",
        &["bits", "n", "entries", "Acc.%", "KiB", "ASIC nJ/inf", "ASIC mm²", "FPGA LUTs", "FPGA kIPS"],
    );
    let mut points = Vec::new();
    for bits in [2usize, 4] {
        for n in [12usize, 20] {
            for entries in [128usize, 1024] {
                let cfg = OneShotConfig {
                    inputs_per_filter: n,
                    entries_per_filter: entries,
                    therm_bits: bits,
                    ..Default::default()
                };
                let (model, _) = train_oneshot(&ds, &cfg);
                let acc = model.evaluate(&ds.test_x, &ds.test_y, ds.num_features).accuracy();
                let asic_inst = AcceleratorInstance::generate(&model, Target::Asic);
                let asic = uleen::hw::asic::implement(&asic_inst);
                let mut fpga_inst = AcceleratorInstance::generate(&model, Target::Fpga);
                let fpga = uleen::hw::fpga::implement(&mut fpga_inst);
                t.row(vec![
                    format!("{bits}"),
                    format!("{n}"),
                    format!("{entries}"),
                    pct(acc),
                    f2(model.size_kib()),
                    f1(asic.nj_per_inf),
                    f2(asic.area_mm2),
                    i0(fpga.luts as f64),
                    i0(fpga.throughput_kips),
                ]);
                points.push((acc, asic.nj_per_inf, format!("b{bits}/n{n}/e{entries}")));
            }
        }
    }
    t.print();

    // energy-accuracy frontier
    points.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut best = 0.0f64;
    println!("energy-ordered frontier (design → accuracy, only improvements):");
    for (acc, nj, label) in &points {
        if *acc > best {
            best = *acc;
            println!("  {label:<16} {:.1} nJ/inf → {:.2}% accuracy", nj, acc * 100.0);
        }
    }
    Ok(())
}
