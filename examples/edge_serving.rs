//! END-TO-END DRIVER — the full three-layer system on a real workload.
//!
//!     make artifacts && cargo run --release --example edge_serving
//!
//! Loads the multi-shot-trained ULN-S model (L2/L1: JAX + Pallas, AOT-
//! lowered to HLO text), serves 20k batched classification requests of
//! SynthMNIST images through the L3 coordinator (bounded queue → dynamic
//! micro-batcher → worker pool) with BOTH engines:
//!
//!   * the native bit-packed Rust engine, and
//!   * the PJRT engine executing the AOT artifact (Python not running!),
//!
//! cross-checks that the two agree prediction-for-prediction, and reports
//! accuracy, throughput and latency percentiles. Results are recorded in
//! EXPERIMENTS.md §E2E.

use std::sync::mpsc;
use std::time::Duration;
use uleen::coordinator::batcher::BatcherConfig;
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_mnist;
use uleen::runtime::{InferenceEngine, NativeEngine, PjrtEngine};

fn serve(
    label: &str,
    make: impl Fn(usize) -> anyhow::Result<Box<dyn InferenceEngine>>,
    ds: &uleen::data::Dataset,
    requests: usize,
    workers: usize,
) -> anyhow::Result<Vec<usize>> {
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            capacity: 8192,
        },
        workers,
    };
    let server = Server::start(cfg, make)?;
    let (tx, rx) = mpsc::channel();
    let n_test = ds.n_test();
    let mut id2idx = std::collections::HashMap::new();
    let mut submitted = 0usize;
    let mut received = 0usize;
    let mut preds = vec![usize::MAX; requests];
    let mut correct = 0usize;
    // Closed-loop load: keep a bounded number of requests in flight so the
    // reported latency is service latency, not open-loop queueing delay.
    let window = 256usize;
    macro_rules! recv_one {
        () => {{
            let (id, p, _) = rx.recv_timeout(Duration::from_secs(60))?;
            let idx = id2idx[&id];
            preds[idx] = p;
            if p == ds.test_y[idx % n_test] as usize {
                correct += 1;
            }
            received += 1;
        }};
    }
    for i in 0..requests {
        let row = ds.test_row(i % n_test).to_vec();
        loop {
            match server.submit(row.clone(), tx.clone()) {
                Ok(id) => {
                    id2idx.insert(id, i);
                    submitted += 1;
                    break;
                }
                Err(uleen::coordinator::batcher::SubmitError::Full) => {
                    std::thread::sleep(Duration::from_micros(20));
                }
                Err(e) => anyhow::bail!("submit: {e:?}"),
            }
        }
        while submitted - received > window {
            recv_one!();
        }
    }
    drop(tx);
    while received < submitted {
        recv_one!();
    }
    let rep = server.metrics.report(16);
    server.shutdown();
    println!(
        "[{label}] {} req | acc {:.4} | {:.0} inf/s | p50/p99 latency {:.0}/{:.0} µs | batch fill {:.0}% | rejected {}",
        submitted,
        correct as f64 / submitted as f64,
        rep.throughput_rps,
        rep.latency_us_p50,
        rep.latency_us_p99,
        rep.mean_batch_fill * 100.0,
        rep.rejected_full
    );
    Ok(preds)
}

fn main() -> anyhow::Result<()> {
    let requests = 20_000;
    // Same seed + split as training: test rows are indices 8000..10000 of
    // the stream, DISJOINT from the model's training data.
    let ds = synth_mnist(2024, 8000, 2000);
    let (model, meta) = uleen::bench::load_model("uln_s.uln")?;
    println!(
        "model: {} ({:.1} KiB, trained acc {:.4})",
        model.name,
        model.size_kib(),
        uleen::bench::meta_accuracy(&meta)
    );

    // Native engine serving.
    let m = model.clone();
    let native_preds = serve(
        "native",
        move |_| Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>),
        &ds,
        requests,
        4,
    )?;

    // PJRT engine serving (the AOT artifact on the hot path).
    let hlo = uleen::bench::artifacts_dir().join("uln_s_b16.hlo.txt");
    let pjrt_preds = serve(
        "pjrt-aot",
        move |_| {
            Ok(Box::new(PjrtEngine::load(&hlo, 16, 784)?) as Box<dyn InferenceEngine>)
        },
        &ds,
        requests,
        2,
    )?;

    let agree = native_preds
        .iter()
        .zip(pjrt_preds.iter())
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "engine agreement: {agree}/{requests} predictions identical ({})",
        if agree == requests { "exact ✓" } else { "MISMATCH ✗" }
    );
    anyhow::ensure!(agree == requests, "native and PJRT engines disagreed");
    Ok(())
}
