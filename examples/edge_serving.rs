//! END-TO-END DRIVER — the full system on a real workload.
//!
//!     cargo run --release --example edge_serving
//!     make artifacts && cargo run --release --features pjrt --example edge_serving
//!
//! Loads the multi-shot-trained ULN-S model when `make artifacts` has run
//! (else a one-shot stand-in), serves 20k batched classification requests
//! of SynthMNIST images through the L3 coordinator (bounded queue →
//! dynamic micro-batcher → worker pool) with the available engines:
//!
//!   * the native bit-packed Rust engine (per-worker engines),
//!   * ONE sharded engine fanning each micro-batch across threads
//!     (the bit-sliced batch kernel × data-parallel shards),
//!   * the tiered ULN-S/M/L zoo — per-worker routers over `Arc`-shared
//!     tiers, then the cascade × shard composition
//!     (`Server::start_zoo_sharded`), and
//!   * with `--features pjrt`: the PJRT engine executing the AOT artifact,
//!
//! cross-checks that the engines agree prediction-for-prediction, and
//! reports accuracy, throughput and latency percentiles. Results are
//! recorded in EXPERIMENTS.md §E2E.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};
use uleen::coordinator::batcher::BatcherConfig;
use uleen::coordinator::http::{client, HttpConfig, HttpFrontend};
use uleen::coordinator::metrics::LATENCY_RESERVOIR_CAP;
use uleen::coordinator::router::{ModelRouter, Tier};
use uleen::coordinator::server::{Server, ServerConfig};
use uleen::data::synth_mnist;
use uleen::runtime::{InferenceEngine, NativeEngine};
use uleen::util::json::Json;

fn config(workers: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig {
            max_batch: 64, // one bit-sliced tile per micro-batch
            max_wait: Duration::from_micros(200),
            capacity: 8192,
        },
        workers,
    }
}

fn serve_on(
    label: &str,
    server: Server,
    ds: &uleen::data::Dataset,
    requests: usize,
) -> anyhow::Result<Vec<usize>> {
    let (tx, rx) = mpsc::channel();
    let n_test = ds.n_test();
    let mut id2idx = std::collections::HashMap::new();
    let mut submitted = 0usize;
    let mut received = 0usize;
    let mut preds = vec![usize::MAX; requests];
    let mut correct = 0usize;
    // Closed-loop load: keep a bounded number of requests in flight so the
    // reported latency is service latency, not open-loop queueing delay.
    let window = 256usize;
    macro_rules! recv_one {
        () => {{
            let (id, p) = rx.recv_timeout(Duration::from_secs(60))?;
            let idx = id2idx[&id];
            preds[idx] = p;
            if p == ds.test_y[idx % n_test] as usize {
                correct += 1;
            }
            received += 1;
        }};
    }
    for i in 0..requests {
        // Borrowed row: submit copies it straight into its arena slot.
        let row = ds.test_row(i % n_test);
        loop {
            match server.submit(row, tx.clone()) {
                Ok(id) => {
                    id2idx.insert(id, i);
                    submitted += 1;
                    break;
                }
                Err(uleen::coordinator::batcher::SubmitError::Full) => {
                    std::thread::sleep(Duration::from_micros(20));
                }
                Err(e) => anyhow::bail!("submit: {e:?}"),
            }
        }
        while submitted - received > window {
            recv_one!();
        }
    }
    drop(tx);
    while received < submitted {
        recv_one!();
    }
    let rep = server.metrics.report(64);
    server.shutdown();
    println!(
        "[{label}] {} req | acc {:.4} | {:.0} inf/s | p50/p99 latency {:.0}/{:.0} µs | batch fill {:.0}% | rejected {}",
        submitted,
        correct as f64 / submitted as f64,
        rep.throughput_rps,
        rep.latency_us_p50,
        rep.latency_us_p99,
        rep.mean_batch_fill * 100.0,
        rep.rejected_full
    );
    Ok(preds)
}

/// Zoo serving leg: build S/M tiers below the served model, start a zoo
/// server, drive mixed cascade + tier-pinned traffic, and assert every
/// prediction equals the local router's (cascade) / the pinned tier's
/// engine (pinned). Prints per-tier counters from the shutdown report.
///
/// `shards == 0` serves per-worker zoos over ONE `Arc`-shared copy of
/// each tier (`Server::start_zoo`); `shards > 0` composes the cascade
/// with shard fan-out (`Server::start_zoo_sharded`): one worker, every
/// micro-batch split into contiguous row ranges that run the cascade in
/// parallel on the persistent pool. Ground truth is identical either
/// way — the sharded cascade is bit-exact by construction.
fn serve_zoo(
    large: &uleen::model::ensemble::UleenModel,
    ds: &uleen::data::Dataset,
    requests: usize,
    shards: usize,
) -> anyhow::Result<()> {
    let mut zoo = Vec::new();
    // the S and M presets below the served model (the shared zoo table)
    for (ipf, epf, bits) in &uleen::train::oneshot::ZOO_PRESET_SHAPES[..2] {
        zoo.push(
            uleen::train::oneshot::train_oneshot(
                ds,
                &uleen::train::oneshot::OneShotConfig {
                    inputs_per_filter: *ipf,
                    entries_per_filter: *epf,
                    therm_bits: *bits,
                    ..Default::default()
                },
            )
            .0,
        );
    }
    zoo.push(large.clone());
    let n_test = ds.n_test();
    // Ground truth: one local router (batched cascade) + each tier alone.
    let mut local = ModelRouter::from_models(&zoo);
    let cascade_want = local.classify_cascade_batch(&ds.test_x, n_test)?;
    let mut tier_want = Vec::new();
    for m in &zoo {
        tier_want.push(NativeEngine::new(m.clone()).classify(&ds.test_x, n_test)?);
    }

    let server = if shards > 0 {
        Server::start_zoo_sharded(config(1), zoo, 0.05, shards)?
    } else {
        Server::start_zoo(config(2), zoo, 0.05)?
    };
    let (tx, rx) = mpsc::channel();
    let mut id2want = std::collections::HashMap::new();
    let mut submitted = 0usize;
    let mut received = 0usize;
    let window = 256usize;
    let tiers = [Tier::Fast, Tier::Balanced, Tier::Accurate];
    macro_rules! recv_one {
        () => {{
            let (id, p) = rx.recv_timeout(Duration::from_secs(60))?;
            let want = id2want[&id];
            anyhow::ensure!(
                p == want,
                "zoo served prediction {p} != ground truth {want} (request {id})"
            );
            received += 1;
        }};
    }
    for i in 0..requests {
        let row = i % n_test;
        let (tier, want) = if i % 4 == 3 {
            let t = (i / 4) % 3;
            (Some(tiers[t]), tier_want[t][row])
        } else {
            (None, cascade_want[row])
        };
        loop {
            match server.submit_tiered(ds.test_row(row), tier, tx.clone()) {
                Ok(id) => {
                    id2want.insert(id, want);
                    submitted += 1;
                    break;
                }
                Err(uleen::coordinator::batcher::SubmitError::Full) => {
                    std::thread::sleep(Duration::from_micros(20));
                }
                Err(e) => anyhow::bail!("submit: {e:?}"),
            }
        }
        while submitted - received > window {
            recv_one!();
        }
    }
    drop(tx);
    while received < submitted {
        recv_one!();
    }
    let rep = server.metrics.report(64);
    server.shutdown();
    let label = if shards > 0 {
        format!("zoo ×3 tiers × {shards} shards")
    } else {
        "zoo ×3 tiers".to_string()
    };
    println!(
        "[{label}] {} req | {:.0} inf/s | p50/p99 latency {:.0}/{:.0} µs | \
         tier served {:?} | escalations {:?}",
        submitted,
        rep.throughput_rps,
        rep.latency_us_p50,
        rep.latency_us_p99,
        rep.tier_served,
        rep.tier_escalations
    );
    println!("[{label}] agreement: batched cascade + pinned tiers vs local ground truth — exact ✓");
    Ok(())
}

/// A native engine slowed to a fixed per-batch service time — makes
/// queue overflow under concurrent load DETERMINISTIC for the overload
/// leg (predictions stay identical to the plain native engine).
struct SlowEngine {
    inner: NativeEngine,
    delay: Duration,
}

impl InferenceEngine for SlowEngine {
    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
    fn num_features(&self) -> usize {
        self.inner.num_features()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn responses_into(&mut self, x: &[f32], n: usize, out: &mut [f32]) -> uleen::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.responses_into(x, n, out)
    }
}

/// HTTP loopback load test: `clients` threads drive real sockets through
/// [`HttpFrontend`] — phase 1 checks every served prediction against
/// local ground truth, phase 2 deliberately overloads a tiny queue and
/// counts well-formed 429s. Writes the `HTTP_loadtest.json` artifact.
fn serve_http_loadtest(
    model: &uleen::model::ensemble::UleenModel,
    ds: &uleen::data::Dataset,
    requests_per_client: usize,
    overload_limit: usize,
) -> anyhow::Result<()> {
    let clients = 8usize;
    let rows_per_req = 8usize;
    let n_test = ds.n_test();
    let want = Arc::new(NativeEngine::new(model.clone()).classify(&ds.test_x, n_test)?);
    let ds = Arc::new(ds.clone());

    // `move` so the only capture (`rows_per_req`, Copy) is taken by
    // value — the closure itself is then Copy + 'static and each client
    // thread gets its own copy.
    let body_for = move |ds: &uleen::data::Dataset, start: usize| {
        let mut j = Json::obj();
        j.set(
            "rows",
            Json::Arr(
                (start..start + rows_per_req)
                    .map(|i| {
                        Json::Arr(ds.test_row(i).iter().map(|&v| Json::Num(v as f64)).collect())
                    })
                    .collect(),
            ),
        );
        j.to_string()
    };

    // ---- phase 1: correctness under concurrency ------------------------
    let mc = model.clone();
    let server = Arc::new(Server::start(config(2), move |_| {
        Ok(Box::new(NativeEngine::new(mc.clone())) as Box<dyn InferenceEngine>)
    })?);
    let frontend = HttpFrontend::start(
        "127.0.0.1:0",
        server.clone(),
        HttpConfig { api_key: Some("edge-key".into()), handlers: 8, ..Default::default() },
    )?;
    let addr = frontend.local_addr().to_string();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let (addr, ds, want) = (addr.clone(), ds.clone(), want.clone());
        handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut mismatches = 0usize;
            for r in 0..requests_per_client {
                let start = (c * 131 + r * rows_per_req) % (ds.n_test() - rows_per_req);
                let body = body_for(&ds, start);
                let resp =
                    client::request(&addr, "POST", "/v1/classify", Some("edge-key"), Some(&body))?;
                anyhow::ensure!(resp.status == 200, "client {c}: HTTP {}: {}", resp.status, resp.body);
                let got: Vec<usize> = Json::parse(&resp.body)
                    .map_err(anyhow::Error::msg)?
                    .get("predictions")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("no predictions in {}", resp.body))?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(-1.0) as usize)
                    .collect();
                mismatches += got
                    .iter()
                    .zip(&want[start..start + rows_per_req])
                    .filter(|(a, b)| a != b)
                    .count();
            }
            Ok(mismatches)
        }));
    }
    let mut mismatches = 0usize;
    for h in handles {
        mismatches += h.join().expect("client thread panicked")?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let served_rows = clients * requests_per_client * rows_per_req;
    let http_rps = clients as f64 * requests_per_client as f64 / elapsed;
    let rep = server.metrics.report(64);
    let (kept, seen) = server.metrics.latency_samples();
    anyhow::ensure!(mismatches == 0, "{mismatches}/{served_rows} HTTP predictions disagreed");
    anyhow::ensure!(
        kept <= LATENCY_RESERVOIR_CAP && seen >= served_rows as u64,
        "latency reservoir out of bounds: kept {kept}, seen {seen}"
    );
    anyhow::ensure!(
        rep.latency_us_p50 > 0.0 && rep.latency_us_p99 >= rep.latency_us_p50,
        "histogram percentiles must populate"
    );
    anyhow::ensure!(
        rep.latency_us_p50_reservoir > 0.0,
        "the reservoir cross-check must populate alongside the histogram"
    );
    frontend.shutdown();
    Arc::try_unwrap(server).ok().expect("server handle leaked").shutdown();
    println!(
        "[http ×{clients} clients] {} req ({served_rows} rows) | agreement exact ✓ | \
         {http_rps:.0} req/s | p50/p99 latency {:.0}/{:.0} µs | reservoir {kept}/{} of {seen}",
        clients * requests_per_client,
        rep.latency_us_p50,
        rep.latency_us_p99,
        LATENCY_RESERVOIR_CAP,
    );

    // ---- phase 2: deliberate overload must 429, never drop -------------
    let mc = model.clone();
    let slow = Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                capacity: 16,
            },
            workers: 1,
        },
        move |_| {
            Ok(Box::new(SlowEngine {
                inner: NativeEngine::new(mc.clone()),
                delay: Duration::from_millis(2),
            }) as Box<dyn InferenceEngine>)
        },
    )?;
    let slow = Arc::new(slow);
    let frontend = HttpFrontend::start(
        "127.0.0.1:0",
        slow.clone(),
        HttpConfig { handlers: 16, ..Default::default() },
    )?;
    let addr = frontend.local_addr().to_string();
    let count_429 = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for c in 0..clients {
        let (addr, ds, count_429) = (addr.clone(), ds.clone(), count_429.clone());
        handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut served = 0usize;
            for r in 0..overload_limit {
                // stop once the fleet has proven the backpressure path
                if count_429.load(Ordering::Relaxed) >= clients {
                    break;
                }
                let start = (c * 17 + r) % (ds.n_test() - rows_per_req);
                let body = body_for(&ds, start);
                let resp = client::request(&addr, "POST", "/v1/classify", None, Some(&body))?;
                match resp.status {
                    200 => served += 1,
                    429 => {
                        anyhow::ensure!(
                            resp.body.contains("queue_full"),
                            "unexpected 429 body: {}",
                            resp.body
                        );
                        count_429.fetch_add(1, Ordering::Relaxed);
                    }
                    s => anyhow::bail!("overload client {c}: HTTP {s}: {}", resp.body),
                }
            }
            Ok(served)
        }));
    }
    let mut overload_served = 0usize;
    for h in handles {
        overload_served += h.join().expect("overload client panicked")?;
    }
    let rejected = count_429.load(Ordering::Relaxed);
    frontend.shutdown();
    Arc::try_unwrap(slow).ok().expect("server handle leaked").shutdown();
    anyhow::ensure!(
        rejected >= 1,
        "deliberate overload produced no 429s ({overload_served} served) — backpressure untested"
    );
    println!(
        "[http overload] {overload_served} served, {rejected} × 429 (queue_full) — \
         every response well-formed, no connection dropped ✓"
    );

    let mut artifact = Json::obj();
    artifact
        .set("clients", Json::Num(clients as f64))
        .set("requests_per_client", Json::Num(requests_per_client as f64))
        .set("rows_per_request", Json::Num(rows_per_req as f64))
        .set("agreement_exact", Json::Bool(mismatches == 0))
        .set("http_rps", Json::Num(http_rps))
        .set("latency_us_p50", Json::Num(rep.latency_us_p50))
        .set("latency_us_p99", Json::Num(rep.latency_us_p99))
        .set("latency_us_p50_reservoir", Json::Num(rep.latency_us_p50_reservoir))
        .set("latency_us_p99_reservoir", Json::Num(rep.latency_us_p99_reservoir))
        .set("reservoir_kept", Json::Num(kept as f64))
        .set("reservoir_seen", Json::Num(seen as f64))
        .set("reservoir_cap", Json::Num(LATENCY_RESERVOIR_CAP as f64))
        .set("overload_served", Json::Num(overload_served as f64))
        .set("overload_429", Json::Num(rejected as f64));
    std::fs::write("HTTP_loadtest.json", artifact.to_string())?;
    println!("wrote HTTP_loadtest.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // `--http-smoke`: the CI gate — run ONLY the HTTP loopback load test,
    // scaled down (8 real-socket clients either way), on a fresh stand-in
    // model. Exercises the full network edge in release mode in seconds.
    if std::env::args().any(|a| a == "--http-smoke") {
        let ds = synth_mnist(2024, 3000, 800);
        let (model, rep) = uleen::train::oneshot::train_oneshot(
            &ds,
            &uleen::train::oneshot::OneShotConfig {
                inputs_per_filter: 16,
                entries_per_filter: 256,
                therm_bits: 2,
                ..Default::default()
            },
        );
        println!("model: {} ({:.1} KiB, val acc {:.4})", model.name, model.size_kib(), rep.val_accuracy);
        return serve_http_loadtest(&model, &ds, 6, 200);
    }
    let requests = 20_000;
    // Same seed + split as training: test rows are indices 8000..10000 of
    // the stream, DISJOINT from the model's training data.
    let ds = synth_mnist(2024, 8000, 2000);
    let model = match uleen::bench::load_model("uln_s.uln") {
        Ok((model, meta)) => {
            println!(
                "model: {} ({:.1} KiB, trained acc {:.4})",
                model.name,
                model.size_kib(),
                uleen::bench::meta_accuracy(&meta)
            );
            model
        }
        Err(e) => {
            println!("(no artifact: {e} — training a one-shot stand-in)");
            let (model, rep) = uleen::train::oneshot::train_oneshot(
                &ds,
                &uleen::train::oneshot::OneShotConfig {
                    inputs_per_filter: 16,
                    entries_per_filter: 256,
                    therm_bits: 4,
                    ..Default::default()
                },
            );
            println!(
                "model: {} ({:.1} KiB, val acc {:.4})",
                model.name,
                model.size_kib(),
                rep.val_accuracy
            );
            model
        }
    };

    // Native engine serving: 4 independent per-worker engines.
    let m = model.clone();
    let native = Server::start(config(4), move |_| {
        Ok(Box::new(NativeEngine::new(m.clone())) as Box<dyn InferenceEngine>)
    })?;
    let native_preds = serve_on("native ×4 workers", native, &ds, requests)?;

    // Sharded serving sweep: one engine, micro-batches fanned N ways.
    for shards in [2usize, 4] {
        let server = Server::start_sharded(config(1), model.clone(), shards)?;
        let preds = serve_on(&format!("sharded ×{shards}"), server, &ds, requests)?;
        anyhow::ensure!(
            preds == native_preds,
            "sharded({shards}) and native engines disagreed"
        );
    }
    println!("engine agreement: native vs sharded — exact ✓");

    // Tiered zoo serving: every worker owns a ULN-S/M/L router over ONE
    // Arc-shared copy of each tier. Default traffic runs the BATCHED
    // confidence cascade (whole micro-batch on the small tier through
    // the fused kernel, thin-margin rows gathered and escalated); every
    // 4th request is pinned to a cycling tier. Every completion is
    // checked against local single-router ground truth — the batched
    // cascade is bit-exact no matter how the dynamic batcher slices the
    // traffic.
    serve_zoo(&model, &ds, 6_000, 0)?;

    // The same zoo with the two scaling axes COMPOSED: one
    // ShardedRouterEngine splits every micro-batch into contiguous row
    // ranges and runs the cascade on 4 pool workers in parallel —
    // predictions and per-tier counters stay bit-exact with the
    // single-router ground truth above.
    serve_zoo(&model, &ds, 6_000, 4)?;

    // The network edge: 8 loopback socket clients against the same model
    // through the HTTP front-end, then a deliberate overload of a tiny
    // queue — backpressure must surface as well-formed 429s.
    serve_http_loadtest(&model, &ds, 40, 400)?;

    // PJRT engine serving (the AOT artifact on the hot path).
    #[cfg(feature = "pjrt")]
    {
        let hlo = uleen::bench::artifacts_dir().join("uln_s_b16.hlo.txt");
        if hlo.exists() {
            let server = Server::start(config(2), move |_| {
                Ok(Box::new(uleen::runtime::PjrtEngine::load(&hlo, 16, 784)?)
                    as Box<dyn InferenceEngine>)
            })?;
            let pjrt_preds = serve_on("pjrt-aot", server, &ds, requests)?;
            let agree = native_preds
                .iter()
                .zip(pjrt_preds.iter())
                .filter(|(a, b)| a == b)
                .count();
            println!(
                "engine agreement: {agree}/{requests} predictions identical ({})",
                if agree == requests { "exact ✓" } else { "MISMATCH ✗" }
            );
            anyhow::ensure!(agree == requests, "native and PJRT engines disagreed");
        } else {
            println!("(skip PJRT serving: {} missing — run `make artifacts`)", hlo.display());
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(skip PJRT serving: built without --features pjrt)");
    Ok(())
}
