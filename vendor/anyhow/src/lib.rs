//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the exact subset of anyhow's surface the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a flattened
//! cause chain of strings (root first); `{e}` prints the outermost
//! message, `{e:#}` the whole chain joined with `: `, and `{e:?}` an
//! anyhow-style "Caused by:" listing. As in the real crate, `Error`
//! deliberately does NOT implement `std::error::Error`, which is what
//! makes the blanket `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// A flattened dynamic error: a cause chain of rendered messages.
pub struct Error {
    /// Cause chain, root cause first; the last entry is the outermost
    /// context (what plain `Display` shows).
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` (the error type defaults like the real crate).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.push(context.to_string());
        self
    }

    /// The cause chain, outermost first (mirrors `anyhow::Error::chain`
    /// closely enough for diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }

    fn outermost(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("unknown error")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        chain.reverse(); // store root cause first
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or a format
/// string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_context_only() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading config".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn debug_lists_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("gone"), "{d}");
    }

    #[test]
    fn option_context_and_question_mark_conversion() {
        fn inner() -> Result<u32> {
            let v: Option<u32> = None;
            let x = v.context("missing value")?;
            Ok(x)
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing value");

        fn io() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(io().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Err(crate::anyhow!("fell through with {}", x))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
        let from_string = crate::anyhow!(String::from("owned message"));
        assert_eq!(from_string.to_string(), "owned message");
    }

    #[test]
    fn ensure_without_message_reports_condition() {
        fn f() -> Result<()> {
            let n = 1;
            crate::ensure!(n > 5);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("n > 5"));
    }
}
