"""L2 model tests: STE semantics, forward equivalences, ensemble addition,
binarization and size accounting."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M


def setup_module():
    np.seterr(over="ignore")


def tiny_ds():
    return D.synth_uci(11, D.uci_spec("iris"))


def tiny_model(n_sub=2):
    ds = tiny_ds()
    subs = tuple(M.SubmodelSpec(6, 32) for _ in range(n_sub))
    spec = M.ModelSpec("tiny", 4, subs)
    return M.init_model(3, spec, ds.train_x, ds.num_classes), ds


def test_step_ste_forward_and_gradient():
    x = jnp.array([-0.5, -0.0, 0.0, 0.7])
    y = M.step_ste(x)
    np.testing.assert_array_equal(np.array(y), [0.0, 1.0, 1.0, 1.0])
    g = jax.grad(lambda v: jnp.sum(M.step_ste(v)))(x)
    np.testing.assert_array_equal(np.array(g), np.ones(4))


def test_train_forward_equals_inference_when_binarized():
    md, ds = tiny_model()
    x = jnp.array(ds.test_x[:8])
    bits = M.encode_bits(x, md["thresholds"])
    # binarize tables → train_forward (no dropout) must equal the
    # inference path on the binarized model.
    for sm in md["submodels"]:
        sm["tables"] = (sm["tables"] >= 0).astype(jnp.float32) * 2.0 - 1.0
    logits_train = np.array(M.train_forward(md["submodels"], bits))
    model_bin = {"thresholds": md["thresholds"],
                 "submodels": [M.binarize_submodel(sm) for sm in md["submodels"]]}
    logits_inf = np.array(M.inference_forward(model_bin, x, use_pallas=False))
    np.testing.assert_array_equal(logits_train, logits_inf)


def test_pallas_and_ref_inference_agree():
    md, ds = tiny_model()
    model_bin = {"thresholds": md["thresholds"],
                 "submodels": [M.binarize_submodel(sm) for sm in md["submodels"]]}
    x = jnp.array(ds.test_x[:8])
    a = np.array(M.inference_forward(model_bin, x, use_pallas=False))
    b = np.array(M.inference_forward(model_bin, x, use_pallas=True, block_b=4))
    np.testing.assert_array_equal(a, b)


def test_ensemble_sums_submodels():
    md, ds = tiny_model(n_sub=2)
    model_bin = {"thresholds": md["thresholds"],
                 "submodels": [M.binarize_submodel(sm) for sm in md["submodels"]]}
    x = jnp.array(ds.test_x[:4])
    full = np.array(M.inference_forward(model_bin, x, use_pallas=False))
    parts = []
    for sm in model_bin["submodels"]:
        one = {"thresholds": md["thresholds"], "submodels": [sm]}
        parts.append(np.array(M.inference_forward(one, x, use_pallas=False)))
    np.testing.assert_allclose(full, parts[0] + parts[1])


def test_zoo_specs_match_paper_table1():
    assert M.ULN_S.therm_bits == 2 and len(M.ULN_S.submodels) == 3
    assert M.ULN_M.therm_bits == 3 and len(M.ULN_M.submodels) == 5
    assert M.ULN_L.therm_bits == 7 and len(M.ULN_L.submodels) == 6
    assert [s.inputs_per_filter for s in M.ULN_L.submodels] == [12, 16, 20, 24, 28, 32]


def test_model_size_accounting():
    md, _ = tiny_model(n_sub=1)
    # iris: 4 features × 4 bits = 16 bits; n=6 → NF=3; 3 classes × 3 × 32 bits
    expected_kib = (3 * 3 * 32) / 8192
    assert abs(M.model_size_kib(md) - expected_kib) < 1e-9
    # pruning half the filters halves the size
    md["submodels"][0]["keep"] = md["submodels"][0]["keep"].at[:, 0].set(0.0)
    assert M.model_size_kib(md) < expected_kib


def test_gradient_flows_to_tables_only_through_addressed_entries():
    md, ds = tiny_model(n_sub=1)
    x = jnp.array(ds.train_x[:16])
    bits = M.encode_bits(x, md["thresholds"])
    y = jnp.array(ds.train_y[:16].astype(np.int32))
    sm = md["submodels"][0]

    def loss(tab):
        s = dict(sm)
        s["tables"] = tab
        logits = M.train_forward([s], bits)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    g = np.array(jax.grad(loss)(sm["tables"]))
    assert (g != 0).any(), "some gradient must flow"
    # gradient sparsity: at most batch × NF × k entries per class touched
    m, nf, e = g.shape
    touched = (g != 0).sum()
    assert touched <= 16 * nf * 2 * m, f"too many touched entries: {touched}"
