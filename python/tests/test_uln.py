"""`.uln` interchange tests: roundtrip fidelity, corruption detection and
semantic equivalence of the reloaded model."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import uln


def setup_module():
    np.seterr(over="ignore")


def make_binarized():
    ds = D.synth_uci(11, D.uci_spec("wine"))
    spec = M.ModelSpec("t", 4, (M.SubmodelSpec(6, 32), M.SubmodelSpec(9, 64)))
    md = M.init_model(5, spec, ds.train_x, ds.num_classes)
    # random binarized tables + a pruned filter + biases
    rng = np.random.default_rng(0)
    for sm in md["submodels"]:
        m, nf, e = sm["tables"].shape
        sm["tables"] = jnp.array(rng.integers(0, 2, (m, nf, e)).astype(np.float32))
        keep = np.ones((m, nf), np.float32)
        keep[1, 0] = 0.0
        sm["keep"] = jnp.array(keep)
        sm["bias"] = jnp.array(rng.integers(-2, 3, (m,)).astype(np.float32))
    mb = {"thresholds": np.asarray(md["thresholds"]),
          "submodels": [{k: np.asarray(v) for k, v in sm.items()} for sm in md["submodels"]]}
    return mb, ds


def test_roundtrip_preserves_arrays():
    mb, _ = make_binarized()
    data = uln.to_bytes(mb, {"name": "t", "test_accuracy": 0.5}, therm_kind=1)
    back, meta = uln.from_bytes(data)
    assert meta["name"] == "t"
    np.testing.assert_array_equal(back["thresholds"], mb["thresholds"])
    for a, b in zip(mb["submodels"], back["submodels"]):
        np.testing.assert_array_equal(a["input_order"], b["input_order"])
        np.testing.assert_array_equal(a["params"], b["params"])
        np.testing.assert_array_equal(a["keep"], b["keep"])
        np.testing.assert_array_equal(a["bias"], b["bias"])
        # pruned filters come back zeroed; kept filters identical
        keep = a["keep"][..., None]
        np.testing.assert_array_equal(a["tables"] * keep, b["tables"] * keep)


def test_roundtrip_preserves_predictions():
    mb, ds = make_binarized()
    data = uln.to_bytes(mb, {"name": "t"}, therm_kind=1)
    back, _ = uln.from_bytes(data)
    x = jnp.array(ds.test_x[:16])
    def predict(model):
        model_j = {"thresholds": jnp.array(model["thresholds"]),
                   "submodels": [{k: jnp.array(v) for k, v in sm.items()}
                                  for sm in model["submodels"]]}
        return np.array(M.predict(model_j, x, use_pallas=False))
    np.testing.assert_array_equal(predict(mb), predict(back))


def test_corruption_detected():
    mb, _ = make_binarized()
    data = bytearray(uln.to_bytes(mb, {}, therm_kind=0))
    data[len(data) // 2] ^= 0xFF
    with pytest.raises(ValueError):
        uln.from_bytes(bytes(data))


def test_truncation_detected():
    mb, _ = make_binarized()
    data = uln.to_bytes(mb, {}, therm_kind=0)
    with pytest.raises(ValueError):
        uln.from_bytes(data[: len(data) - 10])


def test_pack_unpack_bits():
    row = np.array([1, 0, 0, 1, 1, 1, 0, 0, 1, 0, 0, 0, 0, 0, 0, 1], np.float32)
    packed = uln._pack_table_bits(row)
    assert len(packed) == 2
    back = uln._unpack_table_bits(packed, 16)
    np.testing.assert_array_equal(back, row)
