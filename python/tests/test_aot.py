"""AOT export tests: HLO text lowering round-trips through the XLA text
parser with full constants, and executing the lowered computation matches
the JAX forward (the compile-path half of the rust PJRT contract)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile.aot import to_hlo_text


def setup_module():
    np.seterr(over="ignore")


def tiny_binarized():
    ds = D.synth_uci(11, D.uci_spec("iris"))
    spec = M.ModelSpec("t", 4, (M.SubmodelSpec(6, 32),))
    md = M.init_model(5, spec, ds.train_x, ds.num_classes)
    rng = np.random.default_rng(0)
    for sm in md["submodels"]:
        m, nf, e = sm["tables"].shape
        sm["tables"] = jnp.array(rng.integers(0, 2, (m, nf, e)).astype(np.float32))
    return {"thresholds": md["thresholds"], "submodels": md["submodels"]}, ds


def test_hlo_text_has_no_elided_constants():
    mb, ds = tiny_binarized()
    spec = jax.ShapeDtypeStruct((4, ds.num_features), np.float32)
    text = to_hlo_text(lambda x: M.inference_forward(mb, x, use_pallas=True, block_b=4), spec)
    assert "{...}" not in text, "large constants must be fully printed"
    assert "ENTRY" in text


def test_lowered_computation_executes_and_matches_jax():
    from jax._src.lib import xla_client as xc

    mb, ds = tiny_binarized()
    x = np.array(ds.test_x[:4], np.float32)
    spec = jax.ShapeDtypeStruct((4, ds.num_features), np.float32)

    def fn(v):
        return M.inference_forward(mb, v, use_pallas=False)

    text = to_hlo_text(fn, spec)
    # round-trip through the HLO *text* parser (what the rust side does)
    backend = jax.devices()[0].client
    # compile from the text-parsed proto via the mlir path is rust-side;
    # here we at least assert the text parses back into a computation.
    assert text.count("constant") > 0
    expected = np.array(fn(jnp.array(x)))
    got = np.array(jax.jit(fn)(jnp.array(x)))
    np.testing.assert_array_equal(expected, got)
    assert backend is not None


def test_batch1_and_batch16_exports_agree():
    mb, ds = tiny_binarized()
    x = np.array(ds.test_x[:16], np.float32)
    r1 = []
    f1 = jax.jit(lambda v: M.inference_forward(mb, v, use_pallas=True, block_b=1))
    for i in range(16):
        r1.append(np.array(f1(jnp.array(x[i:i + 1]))))
    r1 = np.concatenate(r1, axis=0)
    f16 = jax.jit(lambda v: M.inference_forward(mb, v, use_pallas=True, block_b=8))
    r16 = np.array(f16(jnp.array(x)))
    np.testing.assert_array_equal(r1, r16)
