"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py), with
hypothesis sweeping shapes/dtypes — the core kernel correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bloom, h3, ref


def rand_case(rng, b, nf, n, k, m, e, continuous=False):
    bits = rng.integers(0, 2, (b, nf, n)).astype(np.int32)
    params = rng.integers(0, e, (k, n)).astype(np.int32)
    if continuous:
        tables = rng.uniform(-1, 1, (m, nf, e)).astype(np.float32)
    else:
        tables = rng.integers(0, 2, (m, nf, e)).astype(np.float32)
    keep = (rng.uniform(0, 1, (m, nf)) > 0.3).astype(np.float32)
    bias = rng.integers(-3, 4, (m,)).astype(np.float32)
    return bits, params, tables, keep, bias


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(1, 3),
    block=st.sampled_from([1, 2, 4]),
    nf=st.integers(1, 9),
    n=st.integers(1, 24),
    k=st.integers(1, 4),
    log_e=st.integers(3, 8),
    seed=st.integers(0, 2**31),
)
def test_h3_kernel_matches_ref(b_tiles, block, nf, n, k, log_e, seed):
    rng = np.random.default_rng(seed)
    b = b_tiles * block
    e = 1 << log_e
    bits, params, *_ = rand_case(rng, b, nf, n, k, 3, e)
    got = np.array(h3.h3_hash(jnp.array(bits), jnp.array(params), block_b=block))
    want = np.array(ref.h3_hash_ref(jnp.array(bits), jnp.array(params)))
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32
    assert (got >= 0).all() and (got < e).all()


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(1, 3),
    block=st.sampled_from([1, 2, 4]),
    nf=st.integers(1, 8),
    k=st.integers(1, 3),
    m=st.integers(2, 11),
    log_e=st.integers(3, 7),
    seed=st.integers(0, 2**31),
)
def test_bloom_kernel_matches_ref(b_tiles, block, nf, k, m, log_e, seed):
    rng = np.random.default_rng(seed)
    b = b_tiles * block
    e = 1 << log_e
    idx = rng.integers(0, e, (b, nf, k)).astype(np.int32)
    _, _, tables, keep, bias = rand_case(rng, b, nf, 4, k, m, e)
    got = np.array(bloom.bloom_response(
        jnp.array(idx), jnp.array(tables), jnp.array(keep), jnp.array(bias),
        block_b=block))
    want = np.array(ref.bloom_response_ref(
        jnp.array(idx), jnp.array(tables), jnp.array(keep), jnp.array(bias)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_h3_linearity_through_kernel():
    """h(a xor b) == h(a) xor h(b) holds through the Pallas path too."""
    rng = np.random.default_rng(1)
    n, k, e = 16, 2, 64
    params = rng.integers(0, e, (k, n)).astype(np.int32)
    a = rng.integers(0, 2, (4, 1, n)).astype(np.int32)
    b = rng.integers(0, 2, (4, 1, n)).astype(np.int32)
    hx = np.array(h3.h3_hash(jnp.array(a ^ b), jnp.array(params), block_b=4))
    ha = np.array(h3.h3_hash(jnp.array(a), jnp.array(params), block_b=4))
    hb = np.array(h3.h3_hash(jnp.array(b), jnp.array(params), block_b=4))
    np.testing.assert_array_equal(hx, ha ^ hb)


def test_bloom_and_semantics():
    """response counts filters where ALL k probed entries are 1."""
    tables = np.zeros((1, 2, 8), np.float32)
    tables[0, 0, [1, 2]] = 1.0  # filter 0: entries 1,2 set
    tables[0, 1, 3] = 1.0       # filter 1: only entry 3
    keep = np.ones((1, 2), np.float32)
    bias = np.zeros((1,), np.float32)
    idx = np.array([[[1, 2], [3, 3]],    # f0 both hit, f1 both hit → 2
                    [[1, 0], [3, 3]],    # f0 one miss → 1
                    [[0, 0], [0, 0]]],   # all miss → 0
                   np.int32)
    got = np.array(bloom.bloom_response(
        jnp.array(idx), jnp.array(tables), jnp.array(keep), jnp.array(bias), block_b=3))
    np.testing.assert_array_equal(got[:, 0], [2.0, 1.0, 0.0])


def test_pruned_filters_do_not_count():
    tables = np.ones((1, 3, 8), np.float32)
    keep = np.array([[1.0, 0.0, 1.0]], np.float32)
    bias = np.array([5.0], np.float32)
    idx = np.zeros((1, 3, 2), np.int32)
    got = np.array(bloom.bloom_response(
        jnp.array(idx), jnp.array(tables), jnp.array(keep), jnp.array(bias), block_b=1))
    assert got[0, 0] == 2.0 + 5.0


def test_bad_batch_block_combination_rejected():
    with pytest.raises(AssertionError):
        h3.h3_hash(jnp.zeros((3, 2, 4), jnp.int32), jnp.zeros((2, 4), jnp.int32), block_b=2)


def test_vmem_estimates_positive_and_scale():
    small = h3.vmem_bytes_estimate(8, 16, 12, 2)
    big = h3.vmem_bytes_estimate(8, 64, 12, 2)
    assert 0 < small < big
    assert bloom.vmem_bytes_estimate(8, 10, 131, 64, 2) > 0
