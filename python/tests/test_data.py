"""Dataset generator tests: determinism, spec-conformance and the
cross-language contract (PRNG reference vectors shared with rust)."""

import numpy as np
import pytest

from compile import data as D


def setup_module():
    np.seterr(over="ignore")


def test_splitmix_reference_vector():
    # same vector asserted in rust/src/util/rng.rs
    s = np.array([1234567], dtype=np.uint64)
    out = []
    for _ in range(3):
        s, o = D._splitmix_next(s)
        out.append(int(o[0]))
    assert out == [6457827717110365317, 3203168211198807973, 9817491932198370423]


def test_vecrng_matches_scalar_lanes():
    """each lane of a vector rng equals an independently-seeded stream"""
    idx = np.arange(5, dtype=np.uint64)
    vec = D.VecRng.for_item(99, 7, idx)
    draws = [vec.next_u64() for _ in range(4)]
    for lane in range(5):
        solo = D.VecRng.for_item(99, 7, np.array([lane], dtype=np.uint64))
        for d in draws:
            assert int(solo.next_u64()[0]) == int(d[lane])


def test_mnist_deterministic_and_balanced():
    a, la = D.synth_mnist_images(3, 0, 40)
    b, lb = D.synth_mnist_images(3, 0, 40)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    assert set(la.tolist()) == set(range(10))
    # window generation matches whole generation
    w, lw = D.synth_mnist_images(3, 10, 5)
    np.testing.assert_array_equal(w, a[10:15])


def test_mnist_images_have_ink():
    imgs, _ = D.synth_mnist_images(5, 0, 20)
    assert ((imgs > 128).sum(axis=1) > 20).all()
    assert ((imgs == 0).sum(axis=1) > 300).all()


def test_uci_specs_match_table_iv():
    names = {s.name for s in D.UCI_SPECS}
    assert names == {"ecoli", "iris", "letter", "satimage", "shuttle", "vehicle", "vowel", "wine"}
    iris = D.uci_spec("iris")
    assert (iris.features, iris.classes) == (4, 3)
    with pytest.raises(KeyError):
        D.uci_spec("nope")


def test_shuttle_skew():
    ds = D.synth_uci(3, D.uci_spec("shuttle"))
    frac0 = (ds.train_y == 0).mean()
    assert abs(frac0 - 0.8) < 0.03


def test_checksum_sensitivity():
    ds1 = D.synth_uci(3, D.uci_spec("wine"))
    ds2 = D.synth_uci(4, D.uci_spec("wine"))
    assert ds1.checksum() != ds2.checksum()
    assert ds1.checksum() == D.synth_uci(3, D.uci_spec("wine")).checksum()


def test_uds_export_readable(tmp_path):
    ds = D.synth_uci(3, D.uci_spec("iris"))
    p = tmp_path / "iris.uds"
    D.save_uds(ds, p)
    raw = p.read_bytes()
    assert raw[:4] == b"UDS1"
    # checksum trailer matches recomputation
    import struct
    stored = struct.unpack("<Q", raw[-8:])[0]
    assert stored == ds.checksum()
