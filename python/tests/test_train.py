"""Multi-shot trainer tests: learning actually happens, pruning respects
ratios + adds biases, augmentation shapes, encoder fit properties."""

import numpy as np

from compile import data as D
from compile import encoding
from compile import model as M
from compile import train as T


def setup_module():
    np.seterr(over="ignore")


def test_multishot_learns_iris():
    ds = D.synth_uci(11, D.uci_spec("iris"))
    spec = M.ModelSpec("t", 8, (M.SubmodelSpec(6, 64),))
    md, info = T.train_multishot(spec, ds, epochs=30, finetune_epochs=0,
                                 prune_ratio=0.0, batch=25, lr=0.02,
                                 dropout_p=0.25, log=lambda s: None)
    assert info["test_accuracy"] > 0.8, info["test_accuracy"]


def test_loss_decreases():
    ds = D.synth_uci(12, D.uci_spec("wine"))
    spec = M.ModelSpec("t", 6, (M.SubmodelSpec(8, 64),))
    md = M.init_model(3, spec, ds.train_x, ds.num_classes)
    hist = T.fit(md, ds.train_x, ds.train_y, epochs=10, batch=16,
                 lr=0.02, dropout_p=0.0, log=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_prune_respects_ratio_and_sets_bias():
    ds = D.synth_uci(13, D.uci_spec("vowel"))
    spec = M.ModelSpec("t", 6, (M.SubmodelSpec(6, 64),))
    md, _ = T.train_multishot(spec, ds, epochs=8, finetune_epochs=0,
                              prune_ratio=0.0, batch=32, log=lambda s: None)
    nf = md["submodels"][0]["keep"].shape[1]
    T.prune(md, ds.train_x, ds.train_y, ratio=0.5)
    keep = np.asarray(md["submodels"][0]["keep"])
    expect_kept = nf - int(nf * 0.5)
    assert (keep.sum(axis=1) == expect_kept).all(), keep.sum(axis=1)


def test_tables_stay_clipped():
    ds = D.synth_uci(14, D.uci_spec("iris"))
    spec = M.ModelSpec("t", 4, (M.SubmodelSpec(4, 32),))
    md = M.init_model(3, spec, ds.train_x, ds.num_classes)
    T.fit(md, ds.train_x, ds.train_y, epochs=5, batch=20, lr=0.1,
          dropout_p=0.0, log=lambda s: None)
    tab = np.asarray(md["submodels"][0]["tables"])
    assert tab.min() >= -1.0 and tab.max() <= 1.0


def test_augment_shifts_shapes_and_content():
    imgs = np.zeros((3, 784), np.float32)
    imgs[:, 28 * 14 + 14] = 255.0  # single bright pixel at (14,14)
    labels = np.array([1, 2, 3], np.uint16)
    ax, ay = T.augment_shifts(imgs, labels)
    assert ax.shape == (15, 784)
    assert (ay[:3] == labels).all() and (ay[3:6] == labels).all()
    shifted = ax[3].reshape(28, 28)  # dx=+1 copy
    assert shifted[14, 15] == 255.0


def test_thermometer_fit_gaussian_properties():
    rng = np.random.default_rng(0)
    data = rng.normal(5.0, 2.0, (4000, 2))
    thr = encoding.fit_thermometer(encoding.GAUSSIAN, data, 7)
    assert thr.shape == (2, 7)
    # middle threshold ≈ mean, symmetric spacing
    assert abs(thr[0, 3] - 5.0) < 0.2
    assert np.all(np.diff(thr, axis=1) > 0)
    # ~12.5% of mass in each of the 8 regions
    enc = encoding.encode(data[:, :1], thr[:1])
    level = enc.reshape(-1, 7).sum(axis=1)
    frac = [(level == i).mean() for i in range(8)]
    assert all(abs(f - 0.125) < 0.03 for f in frac), frac


def test_adam_moves_toward_minimum():
    import jax.numpy as jnp
    tab = jnp.array([[4.0]])
    st = {"m": jnp.zeros_like(tab), "v": jnp.zeros_like(tab)}
    x = tab
    for t in range(1, 2000):
        g = 2 * x  # d/dx x^2
        x, st = T.adam_update(x, g, st, float(t), lr=0.01)
    assert abs(float(x[0, 0])) < 0.05
