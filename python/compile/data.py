"""Synthetic datasets — bit-identical mirror of rust/src/data/.

The Rust side generates each sample from an independent PRNG stream
(`Rng::for_item(seed, domain, index)`); here we vectorise those streams
across samples with numpy uint64 arrays (wrapping arithmetic), consuming
draws in EXACTLY the same per-sample order. Integer-only rasterization and
IEEE-exact float derivations keep the two generators bit-identical — the
cross-language checksum test (python/tests/test_data.py + rust data::io)
enforces this.
"""

from dataclasses import dataclass

import numpy as np

U64 = np.uint64
DOMAIN_MNIST = 0x4D4E4953
DOMAIN_UCI = 0x55434931
IMG_W = IMG_H = 28
IMG_PIXELS = IMG_W * IMG_H
Q = 256

_SM_GAMMA = 0x9E3779B97F4A7C15
_SM_M1 = 0xBF58476D1CE4E5B9
_SM_M2 = 0x94D049BB133111EB


def _sm_mix(z):
    z = (z ^ (z >> U64(30))) * U64(_SM_M1)
    z = (z ^ (z >> U64(27))) * U64(_SM_M2)
    return z ^ (z >> U64(31))


def _splitmix_next(state):
    """One SplitMix64 step. Returns (new_state, output); both uint64 arrays."""
    state = state + U64(_SM_GAMMA)
    return state, _sm_mix(state)


def _rotl(x, k):
    return (x << U64(k)) | (x >> U64(64 - k))


class VecRng:
    """Vectorised Xoshiro256** — one independent stream per array lane.

    Mirrors rust `util::rng::Rng` exactly (same seeding via SplitMix64).
    """

    def __init__(self, seeds):
        s = np.asarray(seeds, dtype=np.uint64).copy()
        lanes = []
        for _ in range(4):
            s, out = _splitmix_next(s)
            lanes.append(out)
        self.s = lanes  # list of 4 uint64 arrays

    @classmethod
    def for_item(cls, seed, domain, indices):
        """Mirror of `Rng::for_item` for an array of item indices."""
        idx = np.asarray(indices, dtype=np.uint64)
        sm1 = U64(seed) ^ (U64(domain) * U64(0xA24BAED4963EE407))
        _, a = _splitmix_next(np.broadcast_to(sm1, idx.shape).copy())
        sm2 = a ^ (idx * U64(0x9FB21C651E98DF25))
        _, b = _splitmix_next(sm2)
        return cls(b)

    def next_u64(self):
        s0, s1, s2, s3 = self.s
        r = _rotl(s1 * U64(5), 7) * U64(9)
        t = s1 << U64(17)
        s2 = s2 ^ s0
        s3 = s3 ^ s1
        s1 = s1 ^ s2
        s0 = s0 ^ s3
        s2 = s2 ^ t
        s3 = _rotl(s3, 45)
        self.s = [s0, s1, s2, s3]
        return r

    def below(self, bound):
        return self.next_u64() % U64(bound)

    def range_i64(self, lo, hi):
        return lo + self.below(hi - lo + 1).astype(np.int64)

    def f64(self):
        return (self.next_u64() >> U64(11)).astype(np.float64) * (1.0 / (1 << 53))

    def normal_clt(self):
        acc = np.zeros(np.shape(self.s[0]), dtype=np.float64)
        for _ in range(12):
            acc = acc + self.f64()
        return acc - 6.0


# ---------------------------------------------------------------------------
# SynthMNIST (mirror of rust/src/data/synth_mnist.rs)
# ---------------------------------------------------------------------------

DIGIT_SEGMENTS = {
    0: [(9, 5, 18, 5), (18, 5, 19, 23), (19, 23, 9, 23), (9, 23, 8, 5), (8, 5, 9, 5)],
    1: [(14, 4, 14, 24), (14, 4, 10, 9), (11, 24, 17, 24)],
    2: [(8, 7, 12, 5), (12, 5, 18, 6), (18, 6, 19, 12), (19, 12, 8, 23), (8, 23, 20, 23)],
    3: [(8, 5, 19, 5), (19, 5, 14, 13), (14, 13, 19, 17), (19, 17, 18, 22), (18, 22, 8, 23)],
    4: [(16, 4, 7, 17), (7, 17, 21, 17), (17, 10, 17, 24)],
    5: [(19, 5, 8, 5), (8, 5, 8, 13), (8, 13, 17, 13), (17, 13, 18, 18), (18, 18, 16, 23), (16, 23, 8, 23)],
    6: [(18, 5, 11, 6), (11, 6, 9, 14), (9, 14, 9, 22), (9, 22, 18, 23), (18, 23, 19, 15), (19, 15, 9, 15)],
    7: [(8, 5, 20, 5), (20, 5, 12, 24), (10, 14, 17, 14)],
    8: [(9, 5, 18, 5), (18, 5, 18, 13), (18, 13, 9, 13), (9, 13, 9, 5), (9, 13, 8, 23), (8, 23, 19, 23), (19, 23, 18, 13)],
    9: [(19, 14, 9, 14), (9, 14, 9, 6), (9, 6, 18, 5), (18, 5, 19, 14), (19, 14, 18, 24), (18, 24, 11, 24)],
}

# pixel-centre coordinates in Q8.8, flattened row-major like the rust loop
_PXQ = (np.arange(IMG_W, dtype=np.int64) * Q + Q // 2)[None, :].repeat(IMG_H, axis=0).reshape(-1)
_PYQ = (np.arange(IMG_H, dtype=np.int64) * Q + Q // 2)[:, None].repeat(IMG_W, axis=1).reshape(-1)

MAX_NOISE = 40
MAX_SEGS = 7

# round(sin/cos(d deg)*256) for d in 0..=28 — mirror of rust SIN_Q/COS_Q
SIN_Q = [0, 4, 9, 13, 18, 22, 27, 31, 36, 40, 45, 49, 53, 58, 62, 66, 71, 75,
         79, 83, 88, 92, 96, 100, 104, 108, 112, 116, 120]
COS_Q = [256, 256, 256, 256, 255, 255, 255, 254, 254, 253, 252, 251, 250, 249,
         248, 247, 246, 245, 244, 242, 241, 239, 237, 236, 234, 232, 230, 228, 226]


def _seg_dist2(pxq, pyq, ax, ay, bx, by):
    """Vectorised (over pixels) squared distance to one segment; int64."""
    abx, aby = bx - ax, by - ay
    apx, apy = pxq - ax, pyq - ay
    den = abx * abx + aby * aby
    ap2 = apx * apx + apy * apy
    if den == 0:
        return ap2
    num = apx * abx + apy * aby
    bpx, bpy = pxq - bx, pyq - by
    bp2 = bpx * bpx + bpy * bpy
    mid = ap2 - (num * num) // den
    return np.where(num <= 0, ap2, np.where(num >= den, bp2, mid))


def synth_mnist_images(seed, start, count):
    """Render samples [start, start+count) → (images u8 (count, 784), labels)."""
    idx = np.arange(start, start + count, dtype=np.uint64)
    labels = (idx % U64(10)).astype(np.uint16)
    rng = VecRng.for_item(seed, DOMAIN_MNIST, idx)
    dx = rng.range_i64(-2 * Q, 2 * Q)
    dy = rng.range_i64(-2 * Q, 2 * Q)
    scale = rng.range_i64(225, 287)
    shear = rng.range_i64(-38, 38)
    radius = rng.range_i64(260, 430)
    angle = rng.range_i64(-20, 20)
    seg_jit = [rng.range_i64(-300, 300) for _ in range(4 * MAX_SEGS)]
    seg_drop = [rng.below(100) for _ in range(MAX_SEGS)]
    n_noise = rng.range_i64(10, 40)
    noise_draws = [rng.next_u64() for _ in range(2 * MAX_NOISE)]

    imgs = np.zeros((count, IMG_PIXELS), dtype=np.uint8)
    cx = cy = 14 * Q
    for s in range(count):
        template = DIGIT_SEGMENTS[int(labels[s])]
        r2 = int(radius[s]) ** 2
        best = np.full(IMG_PIXELS, np.iinfo(np.int64).max, dtype=np.int64)
        sc, sh = int(scale[s]), int(shear[s])
        ddx, ddy = int(dx[s]), int(dy[s])
        a = int(angle[s])
        sin_q = -SIN_Q[-a] if a < 0 else SIN_Q[a]
        cos_q = COS_Q[abs(a)]
        dropped = 0
        for si, (x0, y0, x1, y1) in enumerate(template):
            if int(seg_drop[si][s]) < 12 and len(template) - dropped > 2:
                dropped += 1
                continue

            def tf(x, y, jx, jy):
                xq = x * Q - cx
                yq = y * Q - cy
                xr = (xq * cos_q - yq * sin_q) // Q
                yr = (xq * sin_q + yq * cos_q) // Q
                xt = cx + (xr * sc + yr * sh) // Q + ddx + jx
                yt = cy + (yr * sc) // Q + ddy + jy
                return xt, yt

            ax, ay = tf(x0, y0, int(seg_jit[4 * si][s]), int(seg_jit[4 * si + 1][s]))
            bx, by = tf(x1, y1, int(seg_jit[4 * si + 2][s]), int(seg_jit[4 * si + 3][s]))
            d2 = _seg_dist2(_PXQ, _PYQ, ax, ay, bx, by)
            np.minimum(best, d2, out=best)
        hit = best < r2
        v = 255 * (r2 - best) // r2
        v = np.where(best * 25 < r2 * 9, 255, v * 5 // 3)
        img = np.where(hit, np.minimum(v, 255), 0).astype(np.uint8)
        # salt noise, sequential like rust
        nn = int(n_noise[s])
        for t in range(nn):
            pos = int(noise_draws[2 * t][s] % U64(IMG_PIXELS))
            val = int(noise_draws[2 * t + 1][s] % U64(140))
            img[pos] = min(255, int(img[pos]) + 40 + val)
        imgs[s] = img
    return imgs, labels


# ---------------------------------------------------------------------------
# SynthUCI (mirror of rust/src/data/synth_uci.rs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class UciSpec:
    name: str
    id: int
    features: int
    classes: int
    n_train: int
    n_test: int
    skew_permille: int
    spread: float


UCI_SPECS = [
    UciSpec("ecoli", 1, 7, 8, 224, 112, 420, 0.33),
    UciSpec("iris", 2, 4, 3, 100, 50, 0, 0.18),
    UciSpec("letter", 3, 16, 26, 13000, 6500, 0, 0.42),
    UciSpec("satimage", 4, 36, 6, 4435, 2000, 0, 0.40),
    UciSpec("shuttle", 5, 9, 7, 8000, 2000, 800, 0.30),
    UciSpec("vehicle", 6, 18, 4, 564, 282, 0, 0.52),
    UciSpec("vowel", 7, 10, 11, 660, 330, 0, 0.35),
    UciSpec("wine", 8, 13, 3, 118, 60, 0, 0.28),
]


def uci_spec(name):
    for s in UCI_SPECS:
        if s.name == name:
            return s
    raise KeyError(name)


def _uci_centroids(seed, spec):
    rng = VecRng.for_item(seed, DOMAIN_UCI ^ spec.id, np.array([0], dtype=np.uint64))
    vals = [float(rng.f64()[0]) for _ in range(spec.classes * spec.features)]
    return np.array(vals, dtype=np.float64).reshape(spec.classes, spec.features)


def synth_uci_samples(seed, spec, start, count):
    """Samples with stream indices [1+start, 1+start+count) → (x f32, y u16)."""
    idx = np.arange(1 + start, 1 + start + count, dtype=np.uint64)
    rng = VecRng.for_item(seed, DOMAIN_UCI ^ spec.id, idx)
    if spec.skew_permille > 0:
        u = rng.below(1000)
        v = rng.below(spec.classes - 1).astype(np.int64)
        classes = np.where(u < U64(spec.skew_permille), 0, 1 + v).astype(np.uint16)
    else:
        classes = rng.below(spec.classes).astype(np.uint16)
    cents = _uci_centroids(seed, spec)
    x = np.zeros((count, spec.features), dtype=np.float64)
    for f in range(spec.features):
        noise = rng.normal_clt()
        x[:, f] = cents[classes.astype(np.int64), f] + spec.spread * noise
    return x.astype(np.float32), classes


@dataclass
class Dataset:
    name: str
    num_features: int
    num_classes: int
    train_x: np.ndarray  # (n_train, F) float32
    train_y: np.ndarray  # (n_train,) uint16
    test_x: np.ndarray
    test_y: np.ndarray

    def checksum(self):
        """FNV-1a over raw bytes — mirror of rust `Dataset::checksum`."""
        h = 0xCBF29CE484222325
        for arr in (self.train_x, self.test_x):
            for b in arr.reshape(-1).tobytes():
                h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
        for arr in (self.train_y, self.test_y):
            for b in arr.reshape(-1).tobytes():
                h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
        return h


def synth_mnist(seed, n_train, n_test):
    tx, ty = synth_mnist_images(seed, 0, n_train)
    ex, ey = synth_mnist_images(seed, n_train, n_test)
    return Dataset(
        "synth_mnist", IMG_PIXELS, 10,
        tx.astype(np.float32), ty, ex.astype(np.float32), ey,
    )


def synth_uci(seed, spec):
    tx, ty = synth_uci_samples(seed, spec, 0, spec.n_train)
    ex, ey = synth_uci_samples(seed, spec, spec.n_train, spec.n_test)
    return Dataset(f"synth_{spec.name}", spec.features, spec.classes, tx, ty, ex, ey)


def save_uds(ds, path):
    """Write the `.uds` binary format (mirror of rust data::io::save)."""
    import struct

    with open(path, "wb") as f:
        f.write(b"UDS1")
        name = ds.name.encode()
        f.write(struct.pack("<I", len(name)))
        f.write(name)
        f.write(struct.pack("<IIII", ds.num_features, ds.num_classes,
                            len(ds.train_y), len(ds.test_y)))
        f.write(ds.train_x.astype("<f4").tobytes())
        f.write(ds.train_y.astype("<u2").tobytes())
        f.write(ds.test_x.astype("<f4").tobytes())
        f.write(ds.test_y.astype("<u2").tobytes())
        f.write(struct.pack("<Q", ds.checksum()))
