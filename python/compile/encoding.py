"""Thermometer encodings (paper §III-A2) — JAX/numpy side.

Mirrors rust/src/encoding/thermometer.rs: linear thresholds split
[min, max] into equal bins; Gaussian thresholds cut a fitted normal into
t+1 equal-probability regions (Acklam inverse-CDF approximation — same
constants as the Rust side).
"""

import numpy as np

LINEAR, GAUSSIAN = 0, 1

_A = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
      1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
_B = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
      6.680131188771972e+01, -1.328068155288572e+01]
_C = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
      -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
_D = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
      3.754408661907416e+00]


def inv_norm_cdf(p):
    """Acklam's rational approximation of the standard normal quantile."""
    if not 0.0 < p < 1.0:
        raise ValueError("inv_norm_cdf domain")
    plow = 0.02425
    if p < plow:
        q = np.sqrt(-2.0 * np.log(p))
        return (((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
               ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1.0)
    if p <= 1.0 - plow:
        q = p - 0.5
        r = q * q
        return (((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4]) * r + _A[5]) * q / \
               (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r + _B[4]) * r + 1.0)
    q = np.sqrt(-2.0 * np.log(1.0 - p))
    return -(((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
           ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1.0)


def fit_thermometer(kind, data, bits):
    """Fit per-input thresholds.

    data: (n_samples, n_inputs) float array.
    Returns thresholds float32 (n_inputs, bits), increasing along axis 1.
    """
    data = np.asarray(data, dtype=np.float64)
    n, f = data.shape
    thr = np.zeros((f, bits), dtype=np.float64)
    if kind == LINEAR:
        lo = data.min(axis=0)
        hi = data.max(axis=0)
        for i in range(bits):
            thr[:, i] = lo + (hi - lo) * (i + 1.0) / (bits + 1.0)
    elif kind == GAUSSIAN:
        mean = data.mean(axis=0)
        std = data.std(axis=0)  # population std, like the rust fit
        for i in range(bits):
            p = (i + 1.0) / (bits + 1.0)
            z = inv_norm_cdf(p)
            thr[:, i] = np.where(std > 0.0, mean + std * z, mean)
    else:
        raise ValueError(f"unknown thermometer kind {kind}")
    return thr.astype(np.float32)


def encode(x, thresholds):
    """Thermometer-encode a batch: x (B, F) → bits (B, F*bits) in {0,1}.

    Bit layout is input-major (input j's bits occupy [j*bits, (j+1)*bits)),
    matching rust `ThermometerEncoder::encode`. Works under both numpy and
    jax.numpy inputs (pure broadcasting).
    """
    b = (x[:, :, None] > thresholds[None, :, :])
    return b.reshape(x.shape[0], -1)
