"""`.uln` model writer/reader — byte-compatible with rust
`model::uln_format` (see that module's layout doc).

The writer takes a *binarized* model dict (tables in {0,1}) from
compile.model; the reader exists for round-trip tests and for loading
models back into JAX (e.g. to AOT-lower a Rust-trained one-shot model).
"""

import json
import struct

import numpy as np

MAGIC = b"ULN1"
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = 0xFFFFFFFFFFFFFFFF


def _fnv1a(data):
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def _pack_table_bits(row):
    """{0,1} float/int array (E,) → little-endian bytes, LSB-first bits."""
    bits = np.asarray(row) >= 0.5
    return np.packbits(bits, bitorder="little").tobytes()


def _unpack_table_bits(buf, entries):
    bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), bitorder="little")
    return bits[:entries].astype(np.float32)


def to_bytes(model_bin, meta, therm_kind):
    """Serialize a binarized model dict to `.uln` bytes.

    model_bin: {"thresholds": (F, t) f32, "submodels": [dict...]} with
    binary tables. therm_kind: 0 linear / 1 gaussian.
    """
    thr = np.asarray(model_bin["thresholds"], dtype=np.float32)
    f, t = thr.shape
    out = bytearray()
    out += MAGIC
    out += struct.pack("<IIII", 1, therm_kind, f, t)
    out += thr.reshape(-1).astype("<f4").tobytes()
    subs = model_bin["submodels"]
    out += struct.pack("<I", len(subs))
    for sm in subs:
        order = np.asarray(sm["input_order"], dtype=np.uint32)
        params = np.asarray(sm["params"], dtype=np.uint64)
        tables = np.asarray(sm["tables"], dtype=np.float32)
        keep = np.asarray(sm["keep"], dtype=np.float32)
        bias = np.asarray(sm["bias"], dtype=np.float64)
        m, nf, e = tables.shape
        k, n = params.shape
        assert order.shape == (nf, n)
        out += struct.pack("<IIIII", n, e, k, m, nf)
        out += order.reshape(-1).astype("<u4").tobytes()
        out += params.reshape(-1).astype("<u8").tobytes()
        out += np.rint(bias).astype("<i4").tobytes()
        for c in range(m):
            keep_row = (keep[c] > 0.5).astype(np.uint8)
            out += keep_row.tobytes()
            for fidx in range(nf):
                if keep_row[fidx]:
                    out += _pack_table_bits(tables[c, fidx])
    meta_bytes = json.dumps(meta, separators=(",", ":")).encode()
    out += struct.pack("<I", len(meta_bytes))
    out += meta_bytes
    out += struct.pack("<Q", _fnv1a(out))
    return bytes(out)


def save(model_bin, meta, path, therm_kind=1):
    with open(path, "wb") as fh:
        fh.write(to_bytes(model_bin, meta, therm_kind))


def from_bytes(data):
    """Parse `.uln` bytes → (model_bin dict with numpy arrays, meta dict)."""
    body, stored = data[:-8], struct.unpack("<Q", data[-8:])[0]
    if _fnv1a(body) != stored:
        raise ValueError(".uln checksum mismatch")
    off = 0

    def take(n):
        nonlocal off
        if off + n > len(body):
            raise ValueError("truncated .uln")
        s = body[off:off + n]
        off += n
        return s

    if take(4) != MAGIC:
        raise ValueError("bad magic")
    version, kind, f, t = struct.unpack("<IIII", take(16))
    if version != 1:
        raise ValueError(f"unsupported version {version}")
    thr = np.frombuffer(take(f * t * 4), dtype="<f4").reshape(f, t).copy()
    (n_subs,) = struct.unpack("<I", take(4))
    subs = []
    for _ in range(n_subs):
        n, e, k, m, nf = struct.unpack("<IIIII", take(20))
        order = np.frombuffer(take(nf * n * 4), dtype="<u4").reshape(nf, n).astype(np.int32)
        params = np.frombuffer(take(k * n * 8), dtype="<u8").reshape(k, n).astype(np.int64)
        bias = np.frombuffer(take(m * 4), dtype="<i4").astype(np.float32)
        tables = np.zeros((m, nf, e), dtype=np.float32)
        keep = np.zeros((m, nf), dtype=np.float32)
        tb = e // 8
        for c in range(m):
            keep_row = np.frombuffer(take(nf), dtype=np.uint8)
            keep[c] = keep_row.astype(np.float32)
            for fidx in range(nf):
                if keep_row[fidx]:
                    tables[c, fidx] = _unpack_table_bits(take(tb), e)
        subs.append({
            "input_order": order,
            "params": params.astype(np.int32),
            "tables": tables,
            "keep": keep,
            "bias": bias,
        })
    (meta_len,) = struct.unpack("<I", take(4))
    meta = json.loads(take(meta_len).decode())
    if off != len(body):
        raise ValueError("trailing bytes")
    return {"thresholds": thr, "submodels": subs}, meta


def load(path):
    with open(path, "rb") as fh:
        return from_bytes(fh.read())
