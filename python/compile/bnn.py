"""BNN baseline (FINN's SFC/MFC/LFC topologies) trained on SynthMNIST.

Three fully-connected binary hidden layers (sign activations, binarized
weights through the straight-through estimator — the training recipe of
Courbariaux et al. that both FINN and ULEEN's multi-shot rule build on).
Gives the Table II / Fig 11 comparison a same-dataset accuracy instead of
the published real-MNIST numbers. Runs at `make artifacts` time only.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

TOPOLOGIES = {"sfc": 256, "mfc": 512, "lfc": 1024}


def binarize_ste(x):
    """sign(x) in {-1,+1} with straight-through gradient."""
    hard = jnp.where(x >= 0, 1.0, -1.0)
    return x + jax.lax.stop_gradient(hard - x)


def init_params(rng, width, in_dim=784, classes=10, layers=3):
    dims = [in_dim] + [width] * layers + [classes]
    params = []
    for a, b in zip(dims[:-1], dims[1:]):
        w = rng.normal(0, 1.0 / np.sqrt(a), (a, b)).astype(np.float32)
        params.append({"w": jnp.array(w), "g": jnp.ones((b,), jnp.float32),
                       "bta": jnp.zeros((b,), jnp.float32)})
    return params


def forward(params, xbin):
    """xbin in {-1,+1}; binary weights + batch-norm-ish scale + sign."""
    h = xbin
    for i, layer in enumerate(params):
        wb = binarize_ste(layer["w"])
        z = h @ wb
        z = z / np.sqrt(layer["w"].shape[0])  # fan-in scale
        z = z * layer["g"] + layer["bta"]
        if i < len(params) - 1:
            h = binarize_ste(z)
        else:
            h = z
    return h


def loss_fn(params, xbin, y):
    logits = forward(params, xbin)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


@functools.partial(jax.jit, donate_argnums=(0, 3))
def step(params, xbin, y, opt, t, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, xbin, y)
    new_params, new_opt = [], []
    for p, g, o in zip(params, grads, opt):
        np_, no_ = {}, {}
        for k in p:
            m = 0.9 * o[k + "_m"] + 0.1 * g[k]
            v = 0.999 * o[k + "_v"] + 0.001 * g[k] * g[k]
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            upd = p[k] - lr * mh / (jnp.sqrt(vh) + 1e-8)
            if k == "w":
                upd = jnp.clip(upd, -1.0, 1.0)
            np_[k] = upd
            no_[k + "_m"] = m
            no_[k + "_v"] = v
        new_params.append(np_)
        new_opt.append(no_)
    return new_params, new_opt, loss


def binarize_input(x):
    """Paper-style 1-bit input: above per-pixel mean → +1 else −1."""
    return x  # caller pre-thresholds; kept for clarity


def train_bnn(ds, width, epochs=8, batch=96, lr=5e-3, seed=3, log=print):
    rng = np.random.default_rng(seed)
    mean = ds.train_x.mean(axis=0, keepdims=True)
    tx = np.where(ds.train_x > mean, 1.0, -1.0).astype(np.float32)
    ex = np.where(ds.test_x > mean, 1.0, -1.0).astype(np.float32)
    ty = ds.train_y.astype(np.int32)
    params = init_params(rng, width)
    opt = [{k + s: jnp.zeros_like(p[k]) for k in p for s in ("_m", "_v")}
           for p in params]
    n = len(ty)
    t = 0
    for epoch in range(epochs):
        order = rng.permutation(n)
        for s in range(n // batch):
            sel = order[s * batch:(s + 1) * batch]
            t += 1
            params, opt, loss = step(params, jnp.array(tx[sel]),
                                     jnp.array(ty[sel]), opt,
                                     jnp.float32(t), jnp.float32(lr))
        if log:
            pred = np.array(jnp.argmax(forward(params, jnp.array(ex)), -1))
            acc = (pred == ds.test_y).mean()
            log(f"  bnn w={width} epoch {epoch}: loss={float(loss):.3f} acc={acc:.4f}")
    pred = np.array(jnp.argmax(forward(params, jnp.array(ex)), -1))
    return float((pred == ds.test_y).mean())


def train_all(ds, epochs=8, log=print):
    return {name: train_bnn(ds, width, epochs=epochs, log=log)
            for name, width in TOPOLOGIES.items()}


if __name__ == "__main__":
    # standalone: update artifacts/zoo.json with BNN accuracies
    import json
    import sys

    np.seterr(over="ignore")
    from compile import data as D

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    ds = D.synth_mnist(2024, 8000, 2000)
    accs = train_all(ds)
    with open(f"{out}/zoo.json") as fh:
        zoo = json.load(fh)
    zoo["bnn"] = accs
    with open(f"{out}/zoo.json", "w") as fh:
        json.dump(zoo, fh, indent=1)
    print("bnn:", accs)
