"""L1 Pallas kernel: H3 hashing (the paper's central hash block, Fig 9).

Hardware adaptation (DESIGN.md §5): the paper's hash unit is an AND/XOR
gate tree fed by a parameter register file shared across all Bloom filters
of a submodel. On a TPU-shaped target this is a VPU-friendly masked
XOR-reduction — no MXU involvement, mirroring the paper's "arithmetic-free"
claim. The grid tiles the batch; hash parameters ride along as a
whole-array block (they are tiny and live in VMEM for the whole kernel,
like the Param RF).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain
HLO — numerics are identical, scheduling is simulated.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from compile.kernels.ref import xor_reduce


def _h3_kernel(keys_ref, params_ref, out_ref):
    """One batch-tile: keys (TB, NF, n) × params (k, n) → hashes (TB, NF, k)."""
    keys = keys_ref[...].astype(jnp.int32)  # (TB, NF, n)
    params = params_ref[...]  # (k, n)
    masked = keys[:, :, None, :] * params[None, None, :, :]  # (TB, NF, k, n)
    out_ref[...] = xor_reduce(masked, 3)


@functools.partial(jax.jit, static_argnames=("block_b",))
def h3_hash(key_bits, params, block_b=8):
    """Pallas H3 hash: key_bits (B, NF, n) {0,1} int32, params (k, n) int32
    → (B, NF, k) int32. B must be a multiple of block_b (callers pad)."""
    b, nf, n = key_bits.shape
    k = params.shape[0]
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _h3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, nf, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, nf, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nf, k), jnp.int32),
        interpret=True,
    )(key_bits.astype(jnp.int32), params.astype(jnp.int32))


def vmem_bytes_estimate(block_b, nf, n, k):
    """VMEM footprint of one grid step (bytes) — used by the §Perf analysis.

    keys tile + params + masked intermediate + out tile, all int32.
    """
    keys = block_b * nf * n * 4
    params = k * n * 4
    masked = block_b * nf * k * n * 4
    out = block_b * nf * k * 4
    return keys + params + masked + out
