"""Pure-jnp oracle for the L1 Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package is pytest-asserted allclose/equal
against these functions across shapes and dtypes (hypothesis sweeps in
python/tests/test_kernels.py), and the Rust native engine is cross-checked
against the same semantics through the `.uln` interchange.
"""

import jax
import jax.numpy as jnp
import numpy as np


def xor_reduce(x, axis):
    """Bitwise-XOR reduction along `axis` (int32-safe)."""
    return jax.lax.reduce(x, np.int32(0), jax.lax.bitwise_xor, (axis,))


def h3_hash_ref(key_bits, params):
    """H3 family hash of per-filter key bits.

    key_bits: (..., n) int32 in {0,1}
    params:   (k, n) int32 hash parameters (low out_bits used)
    returns:  (..., k) int32 hash values — XOR-fold of params where bits set.
    """
    masked = key_bits[..., None, :] * params  # (..., k, n)
    return xor_reduce(masked, masked.ndim - 1)


def gather_keys_ref(bits, input_order):
    """bits (B, I) → per-filter key bits (B, NF, n) via the shared mapping."""
    return bits[:, input_order]


def bloom_response_ref(idx, tables, keep, bias):
    """Bloom lookup + AND-reduce + per-class popcount.

    idx:    (B, NF, k) int32 hash indices
    tables: (M, NF, E) float32 — binarized {0,1} (inference) or continuous
            (training; caller applies the step themselves)
    keep:   (M, NF) float32 {0,1} prune mask
    bias:   (M,) float32
    returns (B, M) float32 responses: sum_f keep*[min_k table[idx]] + bias.

    For binary tables min-over-k == AND-over-k, matching the hardware's
    1-bit AND accumulator (paper Fig 9).
    """
    # (B, M, NF, k) gather, broadcast over classes
    vals = jnp.take_along_axis(
        tables[None, :, :, :], idx[:, None, :, :], axis=-1
    )
    fired = jnp.min(vals, axis=-1)  # (B, M, NF)
    return jnp.sum(fired * keep[None], axis=-1) + bias[None]


def submodel_forward_ref(bits, input_order, params, tables, keep, bias):
    """Full submodel forward from encoded bits (the fused reference)."""
    keys = gather_keys_ref(bits, input_order)
    h = h3_hash_ref(keys.astype(jnp.int32), params)
    return bloom_response_ref(h, tables, keep, bias)


def ensemble_forward_ref(bits, submodels):
    """Sum of submodel responses (paper Fig 3 'Vectorized Addition').

    submodels: list of dicts with keys input_order, params, tables, keep,
    bias (binarized tables for inference).
    """
    resp = None
    for sm in submodels:
        r = submodel_forward_ref(
            bits, sm["input_order"], sm["params"], sm["tables"], sm["keep"], sm["bias"]
        )
        resp = r if resp is None else resp + r
    return resp
