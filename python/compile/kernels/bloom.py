"""L1 Pallas kernel: Bloom lookup + AND-reduce + per-class popcount.

This is the paper's lockstep lookup stage (Fig 9): once the central hash
block has produced all hash values, every discriminator's lookup units read
their tables simultaneously, AND across the k probes, and the adder trees
sum per-class responses (Fig 8).

Hardware adaptation (DESIGN.md §5): all filter tables of a submodel are
4–75 KiB total — they fit whole in VMEM, exactly like the paper keeps every
table in on-chip LUT RAM with zero BRAM/off-chip traffic. The BlockSpec
therefore maps `tables` as a single whole-array block (the "weights" never
move during the kernel), while the batch dimension is tiled. The gather is
a vectorised dynamic index (VPU); the final per-class reduction is a
popcount-accumulate (the adder-tree analogue).

interpret=True: see h3.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bloom_kernel(idx_ref, tables_ref, keep_ref, bias_ref, out_ref):
    """One batch-tile of Bloom responses.

    idx (TB, NF, k) int32 → out (TB, M) float32.
    """
    idx = idx_ref[...]  # (TB, NF, k)
    tables = tables_ref[...]  # (M, NF, E)
    keep = keep_ref[...]  # (M, NF)
    bias = bias_ref[...]  # (M,)
    vals = jnp.take_along_axis(
        tables[None, :, :, :], idx[:, None, :, :], axis=-1
    )  # (TB, M, NF, k)
    fired = jnp.min(vals, axis=-1)  # AND over the k probes (binary tables)
    out_ref[...] = jnp.sum(fired * keep[None], axis=-1) + bias[None]


@functools.partial(jax.jit, static_argnames=("block_b",))
def bloom_response(idx, tables, keep, bias, block_b=8):
    """Pallas Bloom response: idx (B, NF, k) int32, tables (M, NF, E) f32,
    keep (M, NF) f32, bias (M,) f32 → (B, M) f32."""
    b, nf, k = idx.shape
    m, nf2, e = tables.shape
    assert nf == nf2, "filter-count mismatch"
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _bloom_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, nf, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, nf, e), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, nf), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(idx.astype(jnp.int32), tables.astype(jnp.float32),
      keep.astype(jnp.float32), bias.astype(jnp.float32))


def vmem_bytes_estimate(block_b, m, nf, e, k):
    """VMEM footprint of one grid step (bytes) — §Perf analysis."""
    idx = block_b * nf * k * 4
    tables = m * nf * e * 4  # f32 in the kernel; 1-bit in the real hardware
    gathered = block_b * m * nf * k * 4
    out = block_b * m * 4
    return idx + tables + gathered + out
