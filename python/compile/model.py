"""L2 — the ULEEN ensemble model in JAX (paper §III, Fig 3).

Two forwards over the same parameters:

* `train_forward` — continuous Bloom filters (f32 entries in [-1,1]),
  unit-step binarization through the straight-through estimator, dropout on
  filter outputs; used by the multi-shot trainer (train.py).
* `inference_forward` — binarized tables through the L1 Pallas kernels
  (h3 + bloom); this is the graph that aot.py lowers to HLO text for the
  Rust runtime. A `use_pallas=False` path exists for fast evaluation and
  as an extra oracle.

Parameters of one submodel (a dict, see `init_submodel`):
  input_order (NF, n) int32 | params (k, n) int32 | tables (M, NF, E) f32
  keep (M, NF) f32 {0,1}    | bias (M,) f32
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from compile import encoding
from compile.kernels import bloom as bloom_kernel
from compile.kernels import h3 as h3_kernel
from compile.kernels import ref


@dataclass(frozen=True)
class SubmodelSpec:
    inputs_per_filter: int
    entries_per_filter: int
    k_hashes: int = 2


@dataclass(frozen=True)
class ModelSpec:
    name: str
    therm_bits: int
    submodels: tuple  # tuple[SubmodelSpec, ...]
    therm_kind: int = encoding.GAUSSIAN


# Paper Table I configurations (sizes land within rounding of the paper's
# KiB numbers because the geometry is identical).
ULN_S = ModelSpec("uln_s", 2, (
    SubmodelSpec(12, 64), SubmodelSpec(16, 64), SubmodelSpec(20, 64)))
ULN_M = ModelSpec("uln_m", 3, (
    SubmodelSpec(12, 64), SubmodelSpec(16, 128), SubmodelSpec(20, 256),
    SubmodelSpec(28, 256), SubmodelSpec(36, 512)))
ULN_L = ModelSpec("uln_l", 7, (
    SubmodelSpec(12, 64), SubmodelSpec(16, 128), SubmodelSpec(20, 128),
    SubmodelSpec(24, 256), SubmodelSpec(28, 256), SubmodelSpec(32, 512)))
ZOO = {m.name: m for m in (ULN_S, ULN_M, ULN_L)}


def num_filters(total_bits, n):
    return -(-total_bits // n)  # ceil


def init_submodel(rng, spec, total_bits, num_classes):
    """Random mapping + hash parameters, tables U(-1,1) (paper §III-B2)."""
    n = spec.inputs_per_filter
    nf = num_filters(total_bits, n)
    perm = rng.permutation(total_bits).astype(np.int32)
    order = np.resize(perm, nf * n).reshape(nf, n)
    out_bits = int(np.log2(spec.entries_per_filter))
    params = rng.integers(0, spec.entries_per_filter, (spec.k_hashes, n)).astype(np.int32)
    tables = rng.uniform(-1.0, 1.0, (num_classes, nf, spec.entries_per_filter)).astype(np.float32)
    assert 1 << out_bits == spec.entries_per_filter
    return {
        "input_order": jnp.array(order),
        "params": jnp.array(params),
        "tables": jnp.array(tables),
        "keep": jnp.ones((num_classes, nf), jnp.float32),
        "bias": jnp.zeros((num_classes,), jnp.float32),
    }


def init_model(seed, spec, train_x, num_classes):
    """Fit the encoder on training data and initialise every submodel."""
    thresholds = encoding.fit_thermometer(spec.therm_kind, train_x, spec.therm_bits)
    total_bits = thresholds.size
    rng = np.random.default_rng(seed)
    subs = [init_submodel(rng, s, total_bits, num_classes) for s in spec.submodels]
    return {"thresholds": jnp.array(thresholds), "submodels": subs, "spec": spec}


def step_ste(x):
    """Unit step with straight-through gradient (paper §III-B2):
    forward 1[x>=0], backward identity."""
    hard = (x >= 0.0).astype(jnp.float32)
    return x + jax.lax.stop_gradient(hard - x)


def encode_bits(x, thresholds):
    """Thermometer-encode a raw batch to int32 bits (B, I)."""
    return encoding.encode(x, thresholds).astype(jnp.int32)


def submodel_train_forward(sm, bits, dropout_mask=None):
    """Continuous-filter response with STE binarization.

    dropout_mask: optional (B?, M, NF) {0,1}/p mask applied to filter
    outputs (paper: dropout p=0.5 on the outputs of the filters).
    """
    keys = ref.gather_keys_ref(bits, sm["input_order"]).astype(jnp.int32)
    idx = ref.h3_hash_ref(keys, sm["params"])  # (B, NF, k)
    vals = jnp.take_along_axis(
        sm["tables"][None, :, :, :], idx[:, None, :, :], axis=-1
    )  # (B, M, NF, k)
    m = jnp.min(vals, axis=-1)  # continuous min over probes
    fired = step_ste(m)  # (B, M, NF)
    if dropout_mask is not None:
        fired = fired * dropout_mask
    return jnp.sum(fired * sm["keep"][None], axis=-1) + sm["bias"][None]


def train_forward(submodels, bits, dropout_masks=None):
    """Ensemble logits for training: sum of submodel responses."""
    total = None
    for i, sm in enumerate(submodels):
        mask = None if dropout_masks is None else dropout_masks[i]
        r = submodel_train_forward(sm, bits, mask)
        total = r if total is None else total + r
    return total


def binarize_submodel(sm):
    """Apply the unit step to the continuous tables (post-training)."""
    out = dict(sm)
    out["tables"] = (sm["tables"] >= 0.0).astype(jnp.float32)
    return out


def submodel_infer(sm_bin, bits, use_pallas, block_b):
    keys = ref.gather_keys_ref(bits, sm_bin["input_order"]).astype(jnp.int32)
    if use_pallas:
        idx = h3_kernel.h3_hash(keys, sm_bin["params"], block_b=block_b)
        return bloom_kernel.bloom_response(
            idx, sm_bin["tables"], sm_bin["keep"], sm_bin["bias"], block_b=block_b
        )
    idx = ref.h3_hash_ref(keys, sm_bin["params"])
    return ref.bloom_response_ref(idx, sm_bin["tables"], sm_bin["keep"], sm_bin["bias"])


def inference_forward(model_bin, x, use_pallas=True, block_b=8):
    """Raw pixels → per-class responses. `model_bin` has binarized tables.

    This is the function AOT-lowered to HLO (aot.py): thermometer encode →
    L1 Pallas kernels per submodel → vectorized addition.
    """
    bits = encode_bits(x, model_bin["thresholds"])
    total = None
    for sm in model_bin["submodels"]:
        r = submodel_infer(sm, bits, use_pallas, block_b)
        total = r if total is None else total + r
    return total


def predict(model_bin, x, use_pallas=False, block_b=8):
    return jnp.argmax(inference_forward(model_bin, x, use_pallas, block_b), axis=-1)


def model_size_kib(model_bin):
    """Table bits of kept filters / 8192 — same accounting as the paper."""
    bits = 0
    for sm in model_bin["submodels"]:
        kept = float(jnp.sum(sm["keep"]))
        bits += kept * sm["tables"].shape[-1]
    return bits / 8192.0
