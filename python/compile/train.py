"""Multi-shot training (paper §III-B2, Fig 7b): Adam + cross-entropy over
the STE-binarized continuous Bloom filters, dropout p=0.5 on filter
outputs, optional ±1px shift augmentation for image data; then correlation
pruning + integer biases + fine-tuning (paper §III-A4).

Adam is hand-rolled (optax is not in the offline image); tables are the
only trainable leaves and are clipped to [-1, 1] after every step like the
BNN training recipe ULEEN builds on.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


# ---------------------------------------------------------------------------
# Adam over the per-submodel `tables` leaves
# ---------------------------------------------------------------------------

def adam_init(submodels):
    return [
        {"m": jnp.zeros_like(sm["tables"]), "v": jnp.zeros_like(sm["tables"])}
        for sm in submodels
    ]


def adam_update(tables, grad, state, t, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * state["m"] + (1 - b1) * grad
    v = b2 * state["v"] + (1 - b2) * grad * grad
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    new = tables - lr * mhat / (jnp.sqrt(vhat) + eps)
    return jnp.clip(new, -1.0, 1.0), {"m": m, "v": v}


# ---------------------------------------------------------------------------
# Loss / step
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def _loss_fn(tables_list, static_subs, bits, labels, dropout_masks):
    subs = []
    for sm, tables in zip(static_subs, tables_list):
        s = dict(sm)
        s["tables"] = tables
        subs.append(s)
    logits = M.train_forward(subs, bits, dropout_masks)
    return cross_entropy(logits, labels)


@functools.partial(jax.jit, static_argnames=("dropout_p",), donate_argnums=(0, 4))
def train_step(tables_list, submodels, bits, labels, opt_state, t, key, lr,
               dropout_p=0.5):
    """One Adam step on all submodels' tables (donated buffers — §Perf L2)."""
    masks = None
    if dropout_p > 0:
        keys = jax.random.split(key, len(tables_list))
        masks = []
        for sm, k in zip(submodels, keys):
            m, nf = sm["keep"].shape
            b = bits.shape[0]
            mask = jax.random.bernoulli(k, 1.0 - dropout_p, (b, m, nf))
            masks.append(mask.astype(jnp.float32) / (1.0 - dropout_p))
    loss, grads = jax.value_and_grad(_loss_fn)(
        tables_list, submodels, bits, labels, masks
    )
    new_tables = []
    new_state = []
    for tab, g, st in zip(tables_list, grads, opt_state):
        nt, ns = adam_update(tab, g, st, t, lr=lr)
        new_tables.append(nt)
        new_state.append(ns)
    return new_tables, new_state, loss


# ---------------------------------------------------------------------------
# Data helpers
# ---------------------------------------------------------------------------

def augment_shifts(images, labels, w=28, h=28):
    """±1px horizontal/vertical shifts (paper §III-B2's augmentation,
    reduced from 9 to 5 copies to keep `make artifacts` fast)."""
    imgs = images.reshape(-1, h, w)
    out = [imgs]
    for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        shifted = np.roll(imgs, (dy, dx), axis=(1, 2))
        if dy > 0:
            shifted[:, :dy, :] = 0
        elif dy < 0:
            shifted[:, dy:, :] = 0
        if dx > 0:
            shifted[:, :, :dx] = 0
        elif dx < 0:
            shifted[:, :, dx:] = 0
        out.append(shifted)
    x = np.concatenate(out, axis=0).reshape(-1, h * w)
    y = np.concatenate([labels] * len(out), axis=0)
    return x, y


# ---------------------------------------------------------------------------
# The multi-shot trainer
# ---------------------------------------------------------------------------

def evaluate(model_dict, x, y, batch=512):
    """Accuracy with binarized tables (fast jnp path)."""
    model_bin = {
        "thresholds": model_dict["thresholds"],
        "submodels": [M.binarize_submodel(sm) for sm in model_dict["submodels"]],
    }
    correct = 0
    for i in range(0, len(y), batch):
        xb = jnp.array(x[i:i + batch])
        pred = M.predict(model_bin, xb, use_pallas=False)
        correct += int((np.array(pred) == y[i:i + batch]).sum())
    return correct / len(y)


def fit(model_dict, train_x, train_y, test_x=None, test_y=None, *,
        epochs=10, batch=64, seed=7, dropout_p=0.5, log=print, lr=0.01):
    """Train the tables in place; returns per-epoch history."""
    subs = model_dict["submodels"]
    thresholds = model_dict["thresholds"]
    tables_list = [sm["tables"] for sm in subs]
    static_subs = [dict(sm) for sm in subs]
    opt_state = adam_init(subs)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    n = len(train_y)
    labels_np = np.asarray(train_y, dtype=np.int32)
    history = []
    t = 0
    # Pre-encode once: encoding is static w.r.t. training (tables are the
    # only trainable leaves), saving a threshold-compare per step (§Perf L2).
    encode = jax.jit(lambda xb: M.encode_bits(xb, thresholds))
    for epoch in range(epochs):
        order = rng.permutation(n)
        steps = n // batch
        t0 = time.time()
        epoch_loss = 0.0
        for s in range(steps):
            sel = order[s * batch:(s + 1) * batch]
            xb = jnp.array(train_x[sel])
            yb = jnp.array(labels_np[sel])
            bits = encode(xb)
            key, sub = jax.random.split(key)
            t += 1
            tables_list, opt_state, loss = train_step(
                tables_list, static_subs, bits, yb, opt_state,
                jnp.float32(t), sub, jnp.float32(lr), dropout_p=dropout_p,
            )
            epoch_loss += float(loss)
        for sm, tab in zip(subs, tables_list):
            sm["tables"] = tab
        entry = {"epoch": epoch, "loss": epoch_loss / max(steps, 1),
                 "secs": time.time() - t0}
        if test_x is not None:
            entry["test_acc"] = evaluate(model_dict, test_x, test_y)
        history.append(entry)
        log(f"  epoch {epoch}: loss={entry['loss']:.4f}"
            + (f" test_acc={entry.get('test_acc', 0):.4f}" if test_x is not None else "")
            + f" ({entry['secs']:.1f}s)")
    return history


# ---------------------------------------------------------------------------
# Pruning + bias + fine-tune (paper §III-A4, Fig 7b right)
# ---------------------------------------------------------------------------

def filter_activations(model_dict, x, batch=512):
    """Binarized filter outputs per submodel: list of (N, M, NF) uint8."""
    outs = [[] for _ in model_dict["submodels"]]
    thresholds = model_dict["thresholds"]
    for i in range(0, len(x), batch):
        xb = jnp.array(x[i:i + batch])
        bits = M.encode_bits(xb, thresholds)
        for j, sm in enumerate(model_dict["submodels"]):
            keys = jnp.take(bits, sm["input_order"], axis=1).astype(jnp.int32)
            from compile.kernels import ref
            idx = ref.h3_hash_ref(keys, sm["params"])
            vals = jnp.take_along_axis(
                (sm["tables"] >= 0.0).astype(jnp.float32)[None],
                idx[:, None, :, :], axis=-1)
            fired = jnp.min(vals, axis=-1)  # (B, M, NF)
            outs[j].append(np.array(fired, dtype=np.uint8))
    return [np.concatenate(o, axis=0) for o in outs]


def _phi(n11, n10, n01, n00):
    den = np.sqrt((n11 + n10) * (n01 + n00) * (n11 + n01) * (n10 + n00))
    return np.where(den > 0, (n11 * n00 - n10 * n01) / np.where(den > 0, den, 1.0), 0.0)


def prune(model_dict, train_x, train_y, ratio=0.3):
    """Correlation-prune `ratio` of filters per discriminator; add integer
    biases compensating the lost mean response. Mutates the model."""
    acts = filter_activations(model_dict, train_x)
    y = np.asarray(train_y, dtype=np.int64)
    for sm, a in zip(model_dict["submodels"], acts):
        n, m, nf = a.shape
        keep = np.array(sm["keep"], dtype=np.float32)
        bias = np.array(sm["bias"], dtype=np.float32)
        n_prune = int(nf * ratio)
        for c in range(m):
            is_c = (y == c)
            fired = a[:, c, :].astype(np.float64)  # (N, NF)
            n11 = (fired[is_c] > 0).sum(axis=0).astype(np.float64)
            n01 = is_c.sum() - n11
            n10 = (fired[~is_c] > 0).sum(axis=0).astype(np.float64)
            n00 = (~is_c).sum() - n10
            score = np.abs(_phi(n11, n10, n01, n00))
            score[keep[c] == 0] = np.inf  # already pruned
            order = np.argsort(score, kind="stable")
            victims = order[:n_prune]
            lost = 0.0
            for f in victims:
                if keep[c, f] > 0:
                    keep[c, f] = 0.0
                    lost += n11[f] / max(is_c.sum(), 1)
            bias[c] += round(lost)
        sm["keep"] = jnp.array(keep)
        sm["bias"] = jnp.array(bias)
    return model_dict


def train_multishot(spec, ds, *, seed=7, epochs=10, finetune_epochs=3,
                    prune_ratio=0.3, batch=64, augment=False, log=print,
                    lr=0.01, dropout_p=0.5):
    """The full §III-B2 pipeline: train → prune+bias → fine-tune.

    ds: compile.data.Dataset. Returns (model_dict, info).

    Note on lr: the paper uses 1e-3 with tens of thousands of Adam steps on
    a GPU; our CPU `make artifacts` budget is far smaller, so the default
    is 1e-2 with correspondingly fewer steps (same optimizer trajectory
    family, compressed schedule).
    """
    tx, ty = ds.train_x, ds.train_y
    if augment:
        tx, ty = augment_shifts(tx, ty)
    log(f"[{spec.name}] init ({len(ty)} train samples, "
        f"{len(spec.submodels)} submodels, {spec.therm_bits} bits/input)")
    model_dict = M.init_model(seed, spec, ds.train_x, ds.num_classes)
    hist = fit(model_dict, tx, ty, ds.test_x, ds.test_y,
               epochs=epochs, batch=batch, seed=seed, log=log, lr=lr,
               dropout_p=dropout_p)
    acc_pre = evaluate(model_dict, ds.test_x, ds.test_y)
    if prune_ratio > 0:
        log(f"[{spec.name}] pruning {prune_ratio:.0%} + fine-tune")
        prune(model_dict, ds.train_x, ds.train_y, prune_ratio)
        hist += fit(model_dict, tx, ty, ds.test_x, ds.test_y,
                    epochs=finetune_epochs, batch=batch, seed=seed + 1,
                    log=log, lr=lr / 2, dropout_p=dropout_p)
    acc = evaluate(model_dict, ds.test_x, ds.test_y)
    info = {
        "name": spec.name,
        "test_accuracy": acc,
        "test_accuracy_pre_prune": acc_pre,
        "prune_ratio": prune_ratio,
        "epochs": epochs,
        "history": hist,
    }
    log(f"[{spec.name}] done: acc={acc:.4f} (pre-prune {acc_pre:.4f})")
    return model_dict, info
