"""AOT compile path — the ONE-TIME Python stage (`make artifacts`).

Produces everything the Rust runtime needs, then gets out of the way:

* `artifacts/data/*.uds`            — synthetic datasets (cross-checked
                                      bit-identical with the Rust generators)
* `artifacts/uln_{s,m,l}.uln`       — multi-shot-trained model zoo (Table I)
* `artifacts/uln_l_noprune.uln`,
  `artifacts/ms_single.uln`         — Fig 10 ablation points
* `artifacts/pruned/uln_l_p*.uln`   — Fig 13 pruning sweep family
* `artifacts/uci/uln_<ds>.uln`      — Table IV per-dataset models
* `artifacts/uln_{s,m,l}_b{1,16}.hlo.txt` — inference graphs lowered to HLO
  text (Pallas kernels inlined via interpret mode; HLO TEXT, not serialized
  protos — xla_extension 0.5.1 rejects jax≥0.5's 64-bit ids)
* `artifacts/zoo.json`              — metadata (accuracies, sizes, configs)

Usage: cd python && python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import copy
import json
import os
import time

import jax
import numpy as np

from compile import data as D
from compile import encoding
from compile import model as M
from compile import train as T
from compile import uln

SEED = 2024
MNIST_TRAIN, MNIST_TEST = 8000, 2000


def to_hlo_text(fn, *example_args):
    """Lower a jitted fn to HLO TEXT (see /opt/xla-example/gen_hlo.py)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # "{...}", which the text parser then reads back as garbage — the model
    # tables/thresholds ARE large constants, so full printing is essential.
    return comp.as_hlo_text(print_large_constants=True)


def binarized(model_dict):
    return {
        "thresholds": model_dict["thresholds"],
        "submodels": [M.binarize_submodel(sm) for sm in model_dict["submodels"]],
    }


def export_model(model_dict, meta, path, therm_kind):
    mb = binarized(model_dict)
    uln.save(
        {"thresholds": np.asarray(mb["thresholds"]),
         "submodels": [{k: np.asarray(v) for k, v in sm.items()} for sm in mb["submodels"]]},
        meta, path, therm_kind=therm_kind)
    return mb


def export_hlo(model_bin, batch, num_features, path, block_b):
    x_spec = jax.ShapeDtypeStruct((batch, num_features), np.float32)

    def fn(x):
        return M.inference_forward(model_bin, x, use_pallas=True, block_b=block_b)

    text = to_hlo_text(fn, x_spec)
    with open(path, "w") as fh:
        fh.write(text)
    return len(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny epoch counts (CI smoke, NOT the real build)")
    ap.add_argument("--skip-data", action="store_true")
    args = ap.parse_args()
    np.seterr(over="ignore")
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(f"{out}/data", exist_ok=True)
    os.makedirs(f"{out}/pruned", exist_ok=True)
    os.makedirs(f"{out}/uci", exist_ok=True)
    t_start = time.time()
    zoo = {"seed": SEED, "mnist_train": MNIST_TRAIN, "mnist_test": MNIST_TEST,
           "models": {}, "uci": {}, "pruned": [], "ablation": {}}

    # ---------------- datasets ----------------
    print("== datasets ==", flush=True)
    mnist = D.synth_mnist(SEED, MNIST_TRAIN, MNIST_TEST)
    if not args.skip_data:
        D.save_uds(mnist, f"{out}/data/synth_mnist.uds")
        print(f"  synth_mnist checksum={mnist.checksum():#018x}")
        for spec in D.UCI_SPECS:
            ds = D.synth_uci(SEED, spec)
            D.save_uds(ds, f"{out}/data/synth_{spec.name}.uds")
            print(f"  synth_{spec.name} checksum={ds.checksum():#018x}")

    ep = (3, 1) if args.quick else (15, 4)  # (epochs, finetune)
    ep_l = (2, 1) if args.quick else (10, 3)

    # ---------------- model zoo (Table I) ----------------
    print("== zoo ==", flush=True)
    zoo_models = {}
    for spec, (epochs, ft) in ((M.ULN_S, ep), (M.ULN_M, ep), (M.ULN_L, ep_l)):
        md, info = T.train_multishot(
            spec, mnist, epochs=epochs, finetune_epochs=ft, prune_ratio=0.0,
            batch=64, lr=0.02, dropout_p=0.5)
        # keep the unpruned state for the ablation + pruning sweep
        md_noprune = copy.deepcopy(md)
        T.prune(md, mnist.train_x, mnist.train_y, 0.3)
        T.fit(md, mnist.train_x, mnist.train_y, mnist.test_x, mnist.test_y,
              epochs=ft, batch=64, seed=11, lr=0.01, dropout_p=0.5)
        acc = T.evaluate(md, mnist.test_x, mnist.test_y)
        sub_meta = []
        for s, sm in zip(spec.submodels, md["submodels"]):
            # per-submodel standalone accuracy (paper Table I per-SM rows)
            one = {"thresholds": md["thresholds"], "submodels": [sm]}
            sacc = T.evaluate(one, mnist.test_x, mnist.test_y)
            sub_meta.append({
                "inputs_per_filter": s.inputs_per_filter,
                "entries_per_filter": s.entries_per_filter,
                "accuracy": sacc,
            })
        meta = {
            "name": spec.name, "dataset": "synth_mnist", "trainer": "multishot",
            "test_accuracy": acc, "therm_bits": spec.therm_bits,
            "prune_ratio": 0.3, "submodels": sub_meta,
            "size_kib": M.model_size_kib(md),
        }
        export_model(md, meta, f"{out}/{spec.name}.uln", spec.therm_kind)
        zoo["models"][spec.name] = meta
        zoo_models[spec.name] = (md, md_noprune, info)
        print(f"  {spec.name}: acc={acc:.4f} size={meta['size_kib']:.1f} KiB", flush=True)

    # ---------------- ablation models (Fig 10) ----------------
    print("== ablation ==", flush=True)
    uln_l_md, uln_l_noprune, _ = zoo_models["uln_l"]
    acc_np = T.evaluate(uln_l_noprune, mnist.test_x, mnist.test_y)
    export_model(uln_l_noprune,
                 {"name": "uln_l_noprune", "dataset": "synth_mnist",
                  "test_accuracy": acc_np,
                  "size_kib": M.model_size_kib(uln_l_noprune)},
                 f"{out}/uln_l_noprune.uln", M.ULN_L.therm_kind)
    # single-submodel multi-shot (the "+Multi-shot" ablation point)
    ms_spec = M.ModelSpec("ms_single", 2, (M.SubmodelSpec(16, 256),))
    ms_md, ms_info = T.train_multishot(
        ms_spec, mnist, epochs=ep[0], finetune_epochs=0, prune_ratio=0.0,
        batch=64, lr=0.02, dropout_p=0.5)
    export_model(ms_md,
                 {"name": "ms_single", "dataset": "synth_mnist",
                  "test_accuracy": ms_info["test_accuracy"],
                  "size_kib": M.model_size_kib(ms_md)},
                 f"{out}/ms_single.uln", ms_spec.therm_kind)
    zoo["ablation"] = {
        "ms_single": ms_info["test_accuracy"],
        "uln_l_noprune": acc_np,
        "uln_l": zoo["models"]["uln_l"]["test_accuracy"],
    }

    # ---------------- pruning sweep (Fig 13) ----------------
    print("== pruning sweep ==", flush=True)
    ratios = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.92, 0.94, 0.96, 0.98]
    if args.quick:
        ratios = [0.0, 0.3, 0.7, 0.9]
    for r in ratios:
        mdp = copy.deepcopy(uln_l_noprune)
        if r > 0:
            T.prune(mdp, mnist.train_x, mnist.train_y, r)
            T.fit(mdp, mnist.train_x, mnist.train_y, epochs=1, batch=64,
                  seed=13, lr=0.01, dropout_p=0.5, log=lambda s: None)
        acc = T.evaluate(mdp, mnist.test_x, mnist.test_y)
        size = M.model_size_kib(mdp)
        tag = f"{int(round(r * 100)):02d}"
        export_model(mdp, {"name": f"uln_l_p{tag}", "dataset": "synth_mnist",
                           "test_accuracy": acc, "prune_ratio": r,
                           "size_kib": size},
                     f"{out}/pruned/uln_l_p{tag}.uln", M.ULN_L.therm_kind)
        zoo["pruned"].append({"ratio": r, "accuracy": acc, "size_kib": size})
        print(f"  p={r:.2f}: acc={acc:.4f} size={size:.1f} KiB", flush=True)

    # ---------------- Table IV per-dataset models ----------------
    print("== uci models ==", flush=True)
    uci_epochs = {"letter": 12, "satimage": 12, "shuttle": 12}
    for spec in D.UCI_SPECS:
        ds = D.synth_uci(SEED, spec)
        msub = (M.SubmodelSpec(6, 64), M.SubmodelSpec(9, 64), M.SubmodelSpec(12, 128))
        mspec = M.ModelSpec(f"uln_{spec.name}", 8, msub)
        epochs = uci_epochs.get(spec.name, 50)
        if args.quick:
            epochs = 2
        md, info = T.train_multishot(
            mspec, ds, epochs=epochs, finetune_epochs=max(2, epochs // 6),
            prune_ratio=0.3, batch=32, lr=0.02, dropout_p=0.25,
            log=lambda s: None)
        meta = {"name": mspec.name, "dataset": ds.name, "trainer": "multishot",
                "test_accuracy": info["test_accuracy"],
                "size_kib": M.model_size_kib(md)}
        export_model(md, meta, f"{out}/uci/uln_{spec.name}.uln", mspec.therm_kind)
        zoo["uci"][spec.name] = meta
        print(f"  {spec.name}: acc={info['test_accuracy']:.4f} "
              f"size={meta['size_kib']:.2f} KiB", flush=True)

    # ---------------- BNN baseline (Table II / Fig 11 accuracy) ----------
    print("== bnn baseline ==", flush=True)
    from compile import bnn

    zoo["bnn"] = bnn.train_all(mnist, epochs=2 if args.quick else 8,
                               log=lambda s: print(s, flush=True))

    # ---------------- AOT lowering to HLO text ----------------
    print("== hlo export ==", flush=True)
    for name in ("uln_s", "uln_m", "uln_l"):
        md, _, _ = zoo_models[name]
        mb = binarized(md)
        for batch, block in ((1, 1), (16, 8)):
            path = f"{out}/{name}_b{batch}.hlo.txt"
            nbytes = export_hlo(mb, batch, mnist.num_features, path, block)
            print(f"  {path}: {nbytes} bytes", flush=True)

    zoo["build_seconds"] = time.time() - t_start
    with open(f"{out}/zoo.json", "w") as fh:
        json.dump(zoo, fh, indent=1)
    print(f"== done in {zoo['build_seconds']:.0f}s ==")


if __name__ == "__main__":
    main()
